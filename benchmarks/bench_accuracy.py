"""Table 1 accuracy-trend reproduction at laptop scale.

The paper's claim: batch-wise HRR compression costs <=0.3% accuracy at
R<=16 vs vanilla SL, competitive with BottleNet++.  We reproduce the TREND
on CPU with a conv split model on a synthetic class-conditional image task
(offline environment; see DESIGN.md): C3-SL accuracy within noise of
vanilla SL at R in {2,4,8}, mild drop allowed at 16.

Front: 3 conv blocks -> cut (64, 8, 8), D = 4096 (same D as the paper's
ResNet-50 cut).  Back: 2 conv blocks + fc.  ~300 steps of Adam.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.codecs import build
from repro.core.split import apply_codec
from repro.data.pipeline import SyntheticImageDataset
from repro.models.convnets import _bn, _init_bn, _init_conv, conv2d, max_pool
from repro.optim import adam, apply_updates

CUT = (64, 8, 8)  # D = 4096
D = 64 * 8 * 8


def init_small_convnet(rng, n_classes=10):
    ks = jax.random.split(rng, 6)
    return {
        "c1": _init_conv(ks[0], 3, 32, 3), "b1": _init_bn(32),
        "c2": _init_conv(ks[1], 32, 64, 3), "b2": _init_bn(64),
        "c3": _init_conv(ks[2], 64, 64, 3), "b3": _init_bn(64),
        "c4": _init_conv(ks[3], 64, 128, 3), "b4": _init_bn(128),
        "fc": {"w": jax.random.normal(ks[4], (128, n_classes)) * 128 ** -0.5,
               "b": jnp.zeros((n_classes,))},
    }


def front(p, x):
    x = jax.nn.relu(_bn(conv2d(x, p["c1"]), p["b1"]))
    x = max_pool(x)                                     # 16
    x = jax.nn.relu(_bn(conv2d(x, p["c2"]), p["b2"]))
    x = max_pool(x)                                     # 8
    x = jax.nn.relu(_bn(conv2d(x, p["c3"]), p["b3"]))
    return x                                            # (B, 64, 8, 8)


def back(p, z):
    x = jax.nn.relu(_bn(conv2d(z, p["c4"]), p["b4"]))
    x = x.mean(axis=(2, 3))
    return x @ p["fc"]["w"] + p["fc"]["b"]


def run_one(codec, codec_params_init, steps=300, batch=64, lr=1e-3, seed=0):
    rng = jax.random.PRNGKey(seed)
    # codec params are fixed (random keys, stop_gradient — the paper's whole
    # memory claim), so they stay OUT of the optimized tree
    codec_params = codec_params_init
    params = {"net": init_small_convnet(rng)}
    opt = adam(lr)
    opt_state = opt.init(params)
    data = SyntheticImageDataset(n_classes=10, seed=seed)

    def loss_fn(p, batch_):
        z = front(p["net"], batch_["x"])
        zhat = apply_codec(codec, codec_params, z) if codec is not None else z
        logits = back(p["net"], zhat)
        logp = jax.nn.log_softmax(logits)
        return -logp[jnp.arange(batch_["y"].shape[0]), batch_["y"]].mean()

    @jax.jit
    def step_fn(params, opt_state, batch_):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch_)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    for s in range(steps):
        params, opt_state, loss = step_fn(params, opt_state, data.batch(batch, s))

    # eval on fresh samples
    @jax.jit
    def acc_fn(params, batch_):
        z = front(params["net"], batch_["x"])
        zhat = apply_codec(codec, codec_params, z) if codec is not None else z
        logits = back(params["net"], zhat)
        return (jnp.argmax(logits, -1) == batch_["y"]).mean()

    accs = [float(acc_fn(params, data.batch(256, 10_000 + i))) for i in range(4)]
    return sum(accs) / len(accs)


def main(steps=300):
    rng = jax.random.PRNGKey(42)
    results = {}
    t0 = time.time()
    results["vanilla"] = run_one(None, {}, steps=steps)
    print(f"vanilla,{results['vanilla']*100:.1f}", flush=True)
    for R in (2, 4, 8, 16):
        c = build(f"c3sl:R={R}", D=D)
        results[f"c3sl_R{R}"] = run_one(c, c.init(rng), steps=steps)
        print(f"c3sl_R{R},{results[f'c3sl_R{R}']*100:.1f}", flush=True)
    # beyond-paper: unitary keys (exact-rotation binding) at the hardest R
    cu = build("c3sl:R=16,unitary=true", D=D)
    results["c3sl_R16_unitary"] = run_one(cu, cu.init(rng), steps=steps)
    print(f"c3sl_R16_unitary,{results['c3sl_R16_unitary']*100:.1f}", flush=True)
    # beyond-paper: int8 wire at R=4 (4R x total compression)
    cq = build("c3sl:R=4|int8", D=D)
    results["c3sl_R4_int8"] = run_one(cq, cq.init(rng), steps=steps)
    print(f"c3sl_R4_int8,{results['c3sl_R4_int8']*100:.1f}", flush=True)
    bn = build(f"bnpp:R=4,C={CUT[0]},H={CUT[1]},W={CUT[2]}")
    results["bnpp_R4"] = run_one(bn, bn.init(rng), steps=steps)
    print(f"bnpp_R4,{results['bnpp_R4']*100:.1f}", flush=True)
    print(f"# total {time.time()-t0:.0f}s")
    return results


if __name__ == "__main__":
    main()
