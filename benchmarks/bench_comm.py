"""Communication-cost benchmark: bytes on the SL boundary per training step.

The paper's headline: R x fewer bytes both directions.  Also covers the
beyond-paper int8 wire format (4R x total)."""
from __future__ import annotations

from repro.codecs import build
from repro.configs.paper import RESNET50_CIFAR100, VGG16_CIFAR10
from repro.core.metrics import comm_report


def main():
    print("# boundary traffic per step (fwd+bwd)")
    print("config,method,R,total_bytes,compression_x")
    for cfg in (VGG16_CIFAR10, RESNET50_CIFAR100):
        B, D = cfg.batch_size, cfg.D
        C, H, W = cfg.cut_shape
        rows = [("vanilla", "identity")]
        for R in (2, 4, 8, 16):
            rows.append(("c3sl", f"c3sl:R={R}"))
            rows.append(("c3sl-int8", f"c3sl:R={R}|int8"))
            rows.append(("bottlenet++", f"bnpp:R={R}"))
        for name, spec in rows:
            codec = build(spec, D=D, C=C, H=H, W=W)
            r = comm_report(codec, B, D, method=name)
            print(f"{cfg.name},{name},{getattr(codec,'R',1)},{r.total},"
                  f"{r.compression:.2f}")


if __name__ == "__main__":
    main()
