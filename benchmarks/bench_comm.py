"""Communication-cost benchmark: bytes on the SL boundary per training step.

Two sections:

1. Analytic table — the paper's headline (R x fewer bytes both directions,
   4R x with the int8 wire format) over the paper configs.

2. Adaptive-R sweep — trains a small split MLP on a synthetic workload with
   the ``adaptive:c3sl:...`` scheduler and records the bytes-vs-loss
   TRAJECTORY against every static-R baseline in the bucket ladder.  The
   controller is fed the measured cut-layer retrieval SNR plus a loss-slack
   signal against the static min-R baseline's loss trajectory, so it ramps R
   up exactly when fidelity headroom exists.  Results go to
   ``BENCH_comm.json``; the expectation this suite pins (see
   benchmarks/README.md): **adaptive mean wire bytes <= 0.6x the static
   min-R (max-bytes) run at equal-or-better final loss**, with zero jit
   recompiles across R switches (one compiled branch per bucket — the
   compile counter is asserted in tests/test_adaptive_codec.py and recorded
   here).

    PYTHONPATH=src python -m benchmarks.bench_comm [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import functools
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs, transport
from repro.codecs import build
from repro.configs.paper import RESNET50_CIFAR100, VGG16_CIFAR10
from repro.core import split as split_lib
from repro.core.metrics import comm_report

# Synthetic split-MLP workload for the adaptive sweep: front MLP -> cut
# (B, D_CUT) -> codec -> linear head.  Sized so one run takes seconds on CPU
# while the HRR cross-talk at the ladder's top bucket is clearly visible in
# the cut-layer SNR.
WORKLOAD = {"D_in": 32, "D_hidden": 128, "D_cut": 256, "n_cls": 8,
            "batch": 32, "n_samples": 256, "lr": 0.05, "seed": 0,
            "loss_margin": 0.05, "slack_ema": 0.9}


def analytic_table(results: list) -> None:
    print("# boundary traffic per step (fwd+bwd)")
    print("config,method,R,total_bytes,compression_x")
    for cfg in (VGG16_CIFAR10, RESNET50_CIFAR100):
        B, D = cfg.batch_size, cfg.D
        C, H, W = cfg.cut_shape
        rows = [("vanilla", "identity")]
        for R in (2, 4, 8, 16):
            rows.append(("c3sl", f"c3sl:R={R}"))
            rows.append(("c3sl-int8", f"c3sl:R={R}|int8"))
            rows.append(("bottlenet++", f"bnpp:R={R}"))
        for name, spec in rows:
            codec = build(spec, D=D, C=C, H=H, W=W)
            r = comm_report(codec, B, D, method=name)
            print(f"{cfg.name},{name},{getattr(codec,'R',1)},{r.total},"
                  f"{r.compression:.2f}")
            results.append({"config": cfg.name, "method": name,
                            "R": getattr(codec, "R", 1),
                            "total_bytes": r.total,
                            "compression_x": round(r.compression, 2)})


# ---------------------------------------------------------------------------
# Adaptive-R sweep
# ---------------------------------------------------------------------------

def _workload(w):
    rng = jax.random.PRNGKey(w["seed"])
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    net = {
        "front": {
            "w1": jax.random.normal(k1, (w["D_in"], w["D_hidden"]))
            * w["D_in"] ** -0.5,
            "w2": jax.random.normal(k2, (w["D_hidden"], w["D_cut"]))
            * w["D_hidden"] ** -0.5,
        },
        "back": {"w": jax.random.normal(k3, (w["D_cut"], w["n_cls"]))
                 * w["D_cut"] ** -0.5},
    }
    X = jax.random.normal(k4, (w["n_samples"], w["D_in"]))
    y = jax.random.randint(k5, (w["n_samples"],), 0, w["n_cls"])
    return net, X, y


def _front(p, x):
    return jax.nn.relu(jax.nn.relu(x @ p["w1"]) @ p["w2"])


def _back(p, z):
    return z @ p["w"]


def _ce(logits, y):
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])


def _make_step(codec, codec_params, lr, compile_counter):
    """One jitted SGD step for ONE static codec (an Adaptive-R bucket or a
    static baseline).  ``compile_counter`` increments on TRACE — each bucket
    compiles exactly once, so a schedule that switches R adds nothing."""
    loss_fn = split_lib.make_split_loss_fn(_front, _back, codec, _ce,
                                           with_metrics=True)

    def raw(net, batch):
        compile_counter[0] += 1          # runs only while tracing
        params = {**net, "codec": codec_params}
        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        net2 = jax.tree.map(lambda a, b: a - lr * b,
                            net, {"front": g["front"], "back": g["back"]})
        return net2, loss, m["cut_snr"]

    return jax.jit(raw)


def _batches(X, y, batch, steps):
    n = X.shape[0]
    for t in range(steps):
        lo = (t * batch) % n
        yield {"x": X[lo:lo + batch], "y": y[lo:lo + batch]}


def _run_static(codec_spec, w, steps):
    codec = build(codec_spec, D=w["D_cut"])
    codec_params = codec.init(jax.random.PRNGKey(7))
    net, X, y = _workload(w)
    counter = [0]
    step = _make_step(codec, codec_params, w["lr"], counter)
    losses = []
    for batch in _batches(X, y, w["batch"], steps):
        net, loss, _ = step(net, batch)
        losses.append(float(loss))
    bytes_step = 2 * codec.wire_bytes(w["batch"])
    return {"spec": codec_spec, "R": codec.R,
            "bytes_per_step": bytes_step,
            "total_bytes": bytes_step * steps,
            "final_loss": round(float(np.mean(losses[-20:])), 4),
            "loss_trajectory": [round(l, 4) for l in losses],
            "compiles": counter[0]}


def _run_adaptive(adaptive_spec, w, steps, base_losses):
    """The adaptive run: per-bucket compiled steps, host-side R switching,
    controller fed measured SNR + loss slack vs the min-R baseline's
    trajectory (positive slack = currently matching the conservative run)."""
    codec = build(adaptive_spec, D=w["D_cut"])
    codec_params = codec.init(jax.random.PRNGKey(7))
    net, X, y = _workload(w)
    counter = [0]
    steps_by_R = codecs.build_program_table(
        codec, codec_params,
        lambda bucket, bp: _make_step(bucket, bp, w["lr"], counter))
    # warm every bucket's compiled branch off the clock (same as the engine
    # and train drivers: all branches exist before the schedule runs)
    warm = {"x": X[:w["batch"]], "y": y[:w["batch"]]}
    for R in codec.ladder:
        steps_by_R[R](net, warm)       # compile only; net is not advanced
    compiles_warmup = counter[0]

    traj = []
    total_bytes = 0
    slack = _slack_budget(w, base_losses)
    for t, batch in enumerate(_batches(X, y, w["batch"], steps)):
        R = codec.current_R
        net, loss, snr = steps_by_R[R](net, batch)
        loss = float(loss)
        bucket = codec.buckets[R]
        step_bytes = 2 * bucket.wire_bytes(w["batch"])
        total_bytes += step_bytes
        codec.observe(float(snr), loss_slack=slack(t, loss))
        traj.append({"step": t, "R": R, "loss": round(loss, 4),
                     "snr_db": round(float(snr), 2), "bytes": step_bytes})
    return {"spec": adaptive_spec, "ladder": list(codec.ladder),
            "mean_bytes_per_step": round(total_bytes / steps, 1),
            "total_bytes": total_bytes,
            "final_loss": round(float(np.mean([p["loss"]
                                               for p in traj[-20:]])), 4),
            "final_R": codec.current_R,
            "final_ema_snr": round(codec.ema_snr, 2),
            "compiles": counter[0],
            "compiles_after_warmup": counter[0] - compiles_warmup,
            "trajectory": traj}


def _slack_budget(w, base_losses):
    """ONE definition of the loss-slack veto signal both the shared and
    the directional runs feed their controllers: EMA-smoothed
    ``(budget_trajectory[t] + margin) - loss`` (see benchmarks/README.md —
    the smoothed signal only vetoes/forces on a SUSTAINED gap, per-step CE
    on a 32-sample batch is noisy enough to flip sign)."""
    state = {"ema": None}

    def update(t, loss):
        raw = (base_losses[t] + w["loss_margin"]) - loss
        state["ema"] = (raw if state["ema"] is None
                        else w["slack_ema"] * state["ema"]
                        + (1.0 - w["slack_ema"]) * raw)
        return state["ema"]

    return update


def _make_link_step(link, link_params, lr, compile_counter):
    """One jitted SGD step for ONE static (R_fwd, R_bwd) link pair.  The
    probe argument taps the measured gradient-retrieval SNR (the backward
    controller's feedback) out of the same backward pass."""
    loss_fn = transport.make_split_loss_fn(_front, _back, link, _ce,
                                           with_metrics=True)

    def raw(net, batch, probe):
        compile_counter[0] += 1          # runs only while tracing
        params = {**net, "codec": link_params}
        (loss, m), (g, bwd_snr) = jax.value_and_grad(
            loss_fn, argnums=(0, 2), has_aux=True)(params, batch, probe)
        net2 = jax.tree.map(lambda a, b: a - lr * b,
                            net, {"front": g["front"], "back": g["back"]})
        return net2, loss, m["cut_snr"], bwd_snr

    return jax.jit(raw)


def _run_directional(link_spec, w, steps, base_losses):
    """Per-direction adaptive run: one compiled step per (R_fwd, R_bwd)
    bucket pair, both deadband controllers fed from the SAME step — the
    forward one by the cut-layer retrieval SNR, the backward one by the
    gradient-retrieval SNR measured at the custom-VJP seam — plus the
    shared loss-slack veto vs the min-R baseline's trajectory."""
    link = transport.build_link(link_spec, D=w["D_cut"])
    link_params = link.init(jax.random.PRNGKey(7))
    net, X, y = _workload(w)
    counter = [0]
    steps_by_key = transport.build_link_program_table(
        link, link_params,
        lambda sl, sp: _make_link_step(sl, sp, w["lr"], counter))
    probe0 = jnp.float32(0.0)
    warm = {"x": X[:w["batch"]], "y": y[:w["batch"]]}
    for key in steps_by_key:
        steps_by_key[key](net, warm, probe0)   # compile only
    compiles_warmup = counter[0]

    traj = []
    total_fwd = total_bwd = 0
    slack = _slack_budget(w, base_losses)
    for t, batch in enumerate(_batches(X, y, w["batch"], steps)):
        key = transport.link_program_key(link)
        net, loss, snr, bwd_snr = steps_by_key[key](net, batch, probe0)
        loss = float(loss)
        wf = link.wire_bytes_fwd(w["batch"])
        wb = link.wire_bytes_bwd(w["batch"])
        total_fwd += wf
        total_bwd += wb
        link.observe(fwd_snr=float(snr), bwd_snr=float(bwd_snr),
                     loss_slack=slack(t, loss))
        traj.append({"step": t, "R_fwd": key[0], "R_bwd": key[1],
                     "loss": round(loss, 4),
                     "snr_db": round(float(snr), 2),
                     "grad_snr_db": round(float(bwd_snr), 2),
                     "bytes_fwd": wf, "bytes_bwd": wb})
    return {"spec": link.spec(),
            "ladder_fwd": list(link.fwd.codec.ladder),
            "ladder_bwd": list(link.bwd.codec.ladder),
            "mean_bytes_per_step": round((total_fwd + total_bwd) / steps, 1),
            "total_bytes": total_fwd + total_bwd,
            "total_bytes_fwd": total_fwd,
            "total_bytes_bwd": total_bwd,
            "final_loss": round(float(np.mean([p["loss"]
                                               for p in traj[-20:]])), 4),
            "final_R_fwd": link.fwd.current_R,
            "final_R_bwd": link.bwd.current_R,
            "compiles": counter[0],
            "compiles_after_warmup": counter[0] - compiles_warmup,
            "trajectory": traj}


def directional_sweep(steps: int, shared: dict, base_losses, w=None) -> dict:
    """Per-direction vs shared-R scheduling, same workload and batch order.

    ``shared`` is the PR-4 shared-codec adaptive run (one R for both
    directions, fwd+bwd bytes = 2x the bucket's wire bytes).  The
    directional run reuses the SAME forward spec and adds an independent
    gradient-side controller; the expectation recorded here: **independent
    backward scheduling strictly reduces total wire bytes at equal-or-
    better final loss, with zero post-warmup recompiles** across the
    (R_fwd, R_bwd) program table.
    """
    w = dict(WORKLOAD if w is None else w)
    link_spec = (f"{shared['spec'].split('>>')[0].strip()} >> "
                 f"bwd:adaptive:c3sl:R=4,min_R=2,target_snr=-40")
    print(f"\n# per-direction sweep: {link_spec}")
    # both runs get the SAME loss-slack budget (the static min-R
    # trajectory + margin) so the comparison isolates one variable:
    # whether the backward direction schedules independently
    directional = _run_directional(link_spec, w, steps, base_losses)
    bytes_ratio = directional["total_bytes"] / shared["total_bytes"]
    loss_ok = directional["final_loss"] <= shared["final_loss"]
    print(f"directional {directional['spec']}")
    print(f"         {directional['mean_bytes_per_step']:>7,.0f} B/step mean "
          f"(fwd {directional['total_bytes_fwd']:,d} + "
          f"bwd {directional['total_bytes_bwd']:,d} B total; "
          f"{bytes_ratio:.2f}x the shared-R adaptive run)  final loss "
          f"{directional['final_loss']:.4f} vs shared "
          f"{shared['final_loss']:.4f} "
          f"(R ends at {directional['final_R_fwd']}>>"
          f"bwd:{directional['final_R_bwd']}; "
          f"{directional['compiles_after_warmup']} recompiles after warmup)")
    summary = {
        "shared_spec": shared["spec"],
        "bytes_vs_shared_adaptive": round(bytes_ratio, 3),
        "final_loss_directional": directional["final_loss"],
        "final_loss_shared": shared["final_loss"],
        "meets_criteria": bool(bytes_ratio < 1.0 and loss_ok
                               and directional["compiles_after_warmup"] == 0),
    }
    print(f"# summary: bytes {bytes_ratio:.2f}x shared adaptive, "
          f"meets_criteria={summary['meets_criteria']}")
    return {"directional": directional, "summary": summary}


def adaptive_sweep(steps: int, w=None) -> dict:
    w = dict(WORKLOAD if w is None else w)
    ladder = (2, 4, 8)
    print(f"\n# adaptive-R sweep: split MLP, D_cut={w['D_cut']} "
          f"batch={w['batch']} steps={steps}")
    static = []
    for R in ladder:
        r = _run_static(f"c3sl:R={R}", w, steps)
        static.append(r)
        print(f"static  c3sl:R={R}  {r['bytes_per_step']:>7,d} B/step  "
              f"final loss {r['final_loss']:.4f}  ({r['compiles']} compile)")
    base = static[0]                       # min-R = max bytes = the
    # conservative baseline whose loss trajectory budgets the controller
    adaptive = _run_adaptive(
        f"adaptive:c3sl:R={ladder[-1]},min_R={ladder[0]},target_snr=-20",
        w, steps, base["loss_trajectory"])
    ratio = adaptive["mean_bytes_per_step"] / base["bytes_per_step"]
    loss_ok = adaptive["final_loss"] <= base["final_loss"]
    print(f"adaptive {adaptive['spec']}")
    print(f"         {adaptive['mean_bytes_per_step']:>7,.0f} B/step mean "
          f"({ratio:.2f}x static R={base['R']})  final loss "
          f"{adaptive['final_loss']:.4f} (R ends at {adaptive['final_R']}; "
          f"{adaptive['compiles']} compiles total, "
          f"{adaptive['compiles_after_warmup']} after warmup)")
    summary = {
        "baseline_spec": base["spec"],
        "bytes_vs_static_min_R": round(ratio, 3),
        "final_loss_adaptive": adaptive["final_loss"],
        "final_loss_baseline": base["final_loss"],
        "loss_margin": w["loss_margin"],
        "meets_criteria": bool(ratio <= 0.6 and loss_ok
                               and adaptive["compiles_after_warmup"] == 0),
    }
    print(f"# summary: bytes {ratio:.2f}x baseline, loss "
          f"{adaptive['final_loss']:.4f} vs {base['final_loss']:.4f}, "
          f"meets_criteria={summary['meets_criteria']}")
    # keep the JSON readable: baseline keeps its full trajectory (the
    # controller's budget), other static rows just the summary numbers
    for r in static[1:]:
        r.pop("loss_trajectory")
    return {"workload": {**w, "steps": steps}, "static": static,
            "adaptive": adaptive, "summary": summary}


# ---------------------------------------------------------------------------
# Chaos sweep: goodput + final loss vs fault rate, erasure vs retransmit
# ---------------------------------------------------------------------------

def _make_chaos_step(codec, codec_params, lr, compile_counter, faulty):
    """One jitted SGD step for ONE static bucket that takes the step's
    erasure keep-mask as a runtime argument (static shape per bucket, so
    every lossy step of a bucket shares one compiled branch).  Clean runs
    pin ``erasure=None`` at trace time — the pre-fault program."""
    loss_fn = transport.make_split_loss_fn(_front, _back, codec, _ce,
                                           with_metrics=True)

    def raw(net, batch, erasure):
        compile_counter[0] += 1          # runs only while tracing
        params = {**net, "codec": codec_params}
        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, erasure=erasure)
        net2 = jax.tree.map(lambda a, b: a - lr * b,
                            net, {"front": g["front"], "back": g["back"]})
        return net2, loss, m["cut_snr"]

    if faulty:
        return jax.jit(raw)
    return jax.jit(functools.partial(raw, erasure=None))


def _run_chaos(spec, w, steps, *, rate, mode, fault_seed=11):
    """One chaos training run: the adaptive codec under a seeded FaultPlan
    dropping ``rate`` of the forward cut payload's packets per step,
    recovered per ``mode`` ("erasure" decodes through the renormalized
    mask and lets the degraded SNR drive the controller; "retransmit"
    NACKs until complete and pays the wire bytes).  Returns loss,
    goodput (useful payload bytes / transmitted bytes, retransmissions
    included), and the residual-erasure + R trajectory."""
    codec = build(spec, D=w["D_cut"])
    codec_params = codec.init(jax.random.PRNGKey(7))
    net, X, y = _workload(w)
    counter = [0]
    faulty = rate > 0.0
    link = transport.as_link(codec)
    if faulty:
        link.install_faults(
            transport.FaultPlan(seed=fault_seed, rates={"drop": rate}),
            transport.RecoveryPolicy(mode=mode, retry_budget=8))
    steps_by_R = codecs.build_program_table(
        codec, codec_params,
        lambda bucket, bp: _make_chaos_step(bucket, bp, w["lr"], counter,
                                            faulty))

    losses, r_traj = [], []
    payload_bytes = wire_bytes = 0
    erased_sum = 0.0
    skipped = 0
    for t, batch in enumerate(_batches(X, y, w["batch"], steps)):
        R = codec.current_R
        useful = 2 * codec.buckets[R].wire_bytes(w["batch"]) \
            if isinstance(codec, codecs.AdaptiveC3SL) \
            else 2 * codec.wire_bytes(w["batch"])
        erasure = info = None
        if faulty:
            try:
                erasure, info = link.next_erasure(w["batch"])
            except transport.ChannelErasure:
                # unrecoverable step: at least one full transmission was
                # spent (retransmission traffic of the failed NACK rounds
                # is under-counted here), nothing useful delivered
                skipped += 1
                wire_bytes += useful
                continue
        if faulty:
            net, loss, snr = steps_by_R[R](net, batch, erasure)
        else:
            net, loss, snr = steps_by_R[R](net, batch)
        losses.append(float(loss))
        r_traj.append(R)
        payload_bytes += useful
        mult = info["fwd"]["wire_mult"] if info and info.get("fwd") else 1.0
        # only the forward payload is faulted (mirrored link); the bwd
        # half of `useful` ships clean
        wire_bytes += useful // 2 + int(round((useful // 2) * mult))
        if info and info.get("fwd"):
            erased_sum += info["fwd"]["erased_frac"]
        codec.observe(float(snr))
    done = len(losses)
    return {"rate": rate, "mode": mode if faulty else "clean",
            "steps": steps, "completed": done, "skipped": skipped,
            "final_loss": round(float(np.mean(losses[-20:])), 4),
            "payload_bytes": payload_bytes,
            "wire_bytes": wire_bytes,
            "goodput": round(payload_bytes / max(wire_bytes, 1), 4),
            "mean_erased_frac": round(erased_sum / max(done, 1), 4),
            "final_R": codec.current_R,
            "mean_R": round(float(np.mean(r_traj)), 2) if r_traj else None,
            "compiles": counter[0]}


def chaos_sweep(steps: int, w=None) -> dict:
    """Fault-rate sweep over both recovery modes on the adaptive ladder.

    The expectation this section pins (see benchmarks/README.md): the
    erasure-tolerant decode holds goodput at ~1.0 (no retransmissions —
    loss is absorbed as SNR degradation and, when sustained, an R
    step-down), while retransmit-only pays a growing wire-byte premium
    for the same payload; BOTH modes end at a finite, trained loss at
    every swept rate."""
    w = dict(WORKLOAD if w is None else w)
    spec = "adaptive:c3sl:R=8,min_R=2,target_snr=-20"
    rates = (0.0, 0.05, 0.1, 0.2)
    print(f"\n# chaos sweep: {spec}, drop rates {rates}, "
          f"erasure vs retransmit")
    runs = []
    clean = _run_chaos(spec, w, steps, rate=0.0, mode="erasure")
    runs.append(clean)
    print(f"clean       loss {clean['final_loss']:.4f}  "
          f"goodput {clean['goodput']:.2f}  R ends {clean['final_R']}")
    for mode in ("erasure", "retransmit"):
        for rate in rates[1:]:
            r = _run_chaos(spec, w, steps, rate=rate, mode=mode)
            runs.append(r)
            print(f"{mode:<10} drop={rate:<5} loss {r['final_loss']:.4f}  "
                  f"goodput {r['goodput']:.2f}  "
                  f"erased {r['mean_erased_frac']:.1%}  "
                  f"skipped {r['skipped']}  R ends {r['final_R']}")
    finite = all(np.isfinite(r["final_loss"]) and r["completed"] > 0
                 for r in runs)
    era = [r for r in runs if r["mode"] == "erasure"]
    ret = [r for r in runs if r["mode"] == "retransmit"]
    goodput_ok = all(e["goodput"] >= r["goodput"]
                     for e, r in zip(era, ret))
    summary = {
        "spec": spec,
        "rates": list(rates),
        "all_finite": bool(finite),
        "erasure_goodput_ge_retransmit": bool(goodput_ok),
        "meets_criteria": bool(finite and goodput_ok),
    }
    print(f"# summary: all_finite={finite}, "
          f"erasure goodput >= retransmit at every rate: {goodput_ok}")
    return {"workload": {**w, "steps": steps}, "runs": runs,
            "summary": summary}


def main(out: str = "BENCH_comm.json", sweep_steps: int = 200,
         smoke: bool = False):
    analytic = []
    analytic_table(analytic)
    steps = 40 if smoke else sweep_steps
    sweep = adaptive_sweep(steps)
    directional = directional_sweep(steps, sweep["adaptive"],
                                    sweep["static"][0]["loss_trajectory"])
    chaos = chaos_sweep(steps)
    payload = {
        "protocol": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "host": platform.platform(),
            "device": jax.devices()[0].platform,
            "jax": jax.__version__,
            "smoke": smoke,
        },
        "analytic": analytic,
        "adaptive_sweep": sweep,
        "directional_sweep": directional,
        "chaos_sweep": chaos,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short sweep for CI (seconds)")
    ap.add_argument("--out", default="BENCH_comm.json")
    ap.add_argument("--sweep-steps", type=int, default=200)
    args = ap.parse_args()
    main(out=args.out, sweep_steps=args.sweep_steps, smoke=args.smoke)
