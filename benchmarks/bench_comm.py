"""Communication-cost benchmark: bytes on the SL boundary per training step.

Two sections:

1. Analytic table — the paper's headline (R x fewer bytes both directions,
   4R x with the int8 wire format) over the paper configs.

2. Adaptive-R sweep — trains a small split MLP on a synthetic workload with
   the ``adaptive:c3sl:...`` scheduler and records the bytes-vs-loss
   TRAJECTORY against every static-R baseline in the bucket ladder.  The
   controller is fed the measured cut-layer retrieval SNR plus a loss-slack
   signal against the static min-R baseline's loss trajectory, so it ramps R
   up exactly when fidelity headroom exists.  Results go to
   ``BENCH_comm.json``; the expectation this suite pins (see
   benchmarks/README.md): **adaptive mean wire bytes <= 0.6x the static
   min-R (max-bytes) run at equal-or-better final loss**, with zero jit
   recompiles across R switches (one compiled branch per bucket — the
   compile counter is asserted in tests/test_adaptive_codec.py and recorded
   here).

    PYTHONPATH=src python -m benchmarks.bench_comm [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.codecs import build
from repro.configs.paper import RESNET50_CIFAR100, VGG16_CIFAR10
from repro.core import split as split_lib
from repro.core.metrics import comm_report

# Synthetic split-MLP workload for the adaptive sweep: front MLP -> cut
# (B, D_CUT) -> codec -> linear head.  Sized so one run takes seconds on CPU
# while the HRR cross-talk at the ladder's top bucket is clearly visible in
# the cut-layer SNR.
WORKLOAD = {"D_in": 32, "D_hidden": 128, "D_cut": 256, "n_cls": 8,
            "batch": 32, "n_samples": 256, "lr": 0.05, "seed": 0,
            "loss_margin": 0.05, "slack_ema": 0.9}


def analytic_table(results: list) -> None:
    print("# boundary traffic per step (fwd+bwd)")
    print("config,method,R,total_bytes,compression_x")
    for cfg in (VGG16_CIFAR10, RESNET50_CIFAR100):
        B, D = cfg.batch_size, cfg.D
        C, H, W = cfg.cut_shape
        rows = [("vanilla", "identity")]
        for R in (2, 4, 8, 16):
            rows.append(("c3sl", f"c3sl:R={R}"))
            rows.append(("c3sl-int8", f"c3sl:R={R}|int8"))
            rows.append(("bottlenet++", f"bnpp:R={R}"))
        for name, spec in rows:
            codec = build(spec, D=D, C=C, H=H, W=W)
            r = comm_report(codec, B, D, method=name)
            print(f"{cfg.name},{name},{getattr(codec,'R',1)},{r.total},"
                  f"{r.compression:.2f}")
            results.append({"config": cfg.name, "method": name,
                            "R": getattr(codec, "R", 1),
                            "total_bytes": r.total,
                            "compression_x": round(r.compression, 2)})


# ---------------------------------------------------------------------------
# Adaptive-R sweep
# ---------------------------------------------------------------------------

def _workload(w):
    rng = jax.random.PRNGKey(w["seed"])
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    net = {
        "front": {
            "w1": jax.random.normal(k1, (w["D_in"], w["D_hidden"]))
            * w["D_in"] ** -0.5,
            "w2": jax.random.normal(k2, (w["D_hidden"], w["D_cut"]))
            * w["D_hidden"] ** -0.5,
        },
        "back": {"w": jax.random.normal(k3, (w["D_cut"], w["n_cls"]))
                 * w["D_cut"] ** -0.5},
    }
    X = jax.random.normal(k4, (w["n_samples"], w["D_in"]))
    y = jax.random.randint(k5, (w["n_samples"],), 0, w["n_cls"])
    return net, X, y


def _front(p, x):
    return jax.nn.relu(jax.nn.relu(x @ p["w1"]) @ p["w2"])


def _back(p, z):
    return z @ p["w"]


def _ce(logits, y):
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])


def _make_step(codec, codec_params, lr, compile_counter):
    """One jitted SGD step for ONE static codec (an Adaptive-R bucket or a
    static baseline).  ``compile_counter`` increments on TRACE — each bucket
    compiles exactly once, so a schedule that switches R adds nothing."""
    loss_fn = split_lib.make_split_loss_fn(_front, _back, codec, _ce,
                                           with_metrics=True)

    def raw(net, batch):
        compile_counter[0] += 1          # runs only while tracing
        params = {**net, "codec": codec_params}
        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        net2 = jax.tree.map(lambda a, b: a - lr * b,
                            net, {"front": g["front"], "back": g["back"]})
        return net2, loss, m["cut_snr"]

    return jax.jit(raw)


def _batches(X, y, batch, steps):
    n = X.shape[0]
    for t in range(steps):
        lo = (t * batch) % n
        yield {"x": X[lo:lo + batch], "y": y[lo:lo + batch]}


def _run_static(codec_spec, w, steps):
    codec = build(codec_spec, D=w["D_cut"])
    codec_params = codec.init(jax.random.PRNGKey(7))
    net, X, y = _workload(w)
    counter = [0]
    step = _make_step(codec, codec_params, w["lr"], counter)
    losses = []
    for batch in _batches(X, y, w["batch"], steps):
        net, loss, _ = step(net, batch)
        losses.append(float(loss))
    bytes_step = 2 * codec.wire_bytes(w["batch"])
    return {"spec": codec_spec, "R": codec.R,
            "bytes_per_step": bytes_step,
            "total_bytes": bytes_step * steps,
            "final_loss": round(float(np.mean(losses[-20:])), 4),
            "loss_trajectory": [round(l, 4) for l in losses],
            "compiles": counter[0]}


def _run_adaptive(adaptive_spec, w, steps, base_losses):
    """The adaptive run: per-bucket compiled steps, host-side R switching,
    controller fed measured SNR + loss slack vs the min-R baseline's
    trajectory (positive slack = currently matching the conservative run)."""
    codec = build(adaptive_spec, D=w["D_cut"])
    codec_params = codec.init(jax.random.PRNGKey(7))
    net, X, y = _workload(w)
    counter = [0]
    steps_by_R = codecs.build_program_table(
        codec, codec_params,
        lambda bucket, bp: _make_step(bucket, bp, w["lr"], counter))
    # warm every bucket's compiled branch off the clock (same as the engine
    # and train drivers: all branches exist before the schedule runs)
    warm = {"x": X[:w["batch"]], "y": y[:w["batch"]]}
    for R in codec.ladder:
        steps_by_R[R](net, warm)       # compile only; net is not advanced
    compiles_warmup = counter[0]

    traj = []
    total_bytes = 0
    slack_ema = None
    for t, batch in enumerate(_batches(X, y, w["batch"], steps)):
        R = codec.current_R
        net, loss, snr = steps_by_R[R](net, batch)
        loss = float(loss)
        bucket = codec.buckets[R]
        step_bytes = 2 * bucket.wire_bytes(w["batch"])
        total_bytes += step_bytes
        # loss slack vs the conservative baseline's trajectory, EMA-smoothed:
        # per-step CE on a 32-sample batch is noisy enough to flip sign and
        # ping-pong the ladder; the smoothed signal only vetoes ramp-ups
        # (or forces ramp-downs) on a SUSTAINED loss gap
        raw = (base_losses[t] + w["loss_margin"]) - loss
        slack_ema = (raw if slack_ema is None
                     else w["slack_ema"] * slack_ema
                     + (1.0 - w["slack_ema"]) * raw)
        codec.observe(float(snr), loss_slack=slack_ema)
        traj.append({"step": t, "R": R, "loss": round(loss, 4),
                     "snr_db": round(float(snr), 2), "bytes": step_bytes})
    return {"spec": adaptive_spec, "ladder": list(codec.ladder),
            "mean_bytes_per_step": round(total_bytes / steps, 1),
            "total_bytes": total_bytes,
            "final_loss": round(float(np.mean([p["loss"]
                                               for p in traj[-20:]])), 4),
            "final_R": codec.current_R,
            "final_ema_snr": round(codec.ema_snr, 2),
            "compiles": counter[0],
            "compiles_after_warmup": counter[0] - compiles_warmup,
            "trajectory": traj}


def adaptive_sweep(steps: int, w=None) -> dict:
    w = dict(WORKLOAD if w is None else w)
    ladder = (2, 4, 8)
    print(f"\n# adaptive-R sweep: split MLP, D_cut={w['D_cut']} "
          f"batch={w['batch']} steps={steps}")
    static = []
    for R in ladder:
        r = _run_static(f"c3sl:R={R}", w, steps)
        static.append(r)
        print(f"static  c3sl:R={R}  {r['bytes_per_step']:>7,d} B/step  "
              f"final loss {r['final_loss']:.4f}  ({r['compiles']} compile)")
    base = static[0]                       # min-R = max bytes = the
    # conservative baseline whose loss trajectory budgets the controller
    adaptive = _run_adaptive(
        f"adaptive:c3sl:R={ladder[-1]},min_R={ladder[0]},target_snr=-20",
        w, steps, base["loss_trajectory"])
    ratio = adaptive["mean_bytes_per_step"] / base["bytes_per_step"]
    loss_ok = adaptive["final_loss"] <= base["final_loss"]
    print(f"adaptive {adaptive['spec']}")
    print(f"         {adaptive['mean_bytes_per_step']:>7,.0f} B/step mean "
          f"({ratio:.2f}x static R={base['R']})  final loss "
          f"{adaptive['final_loss']:.4f} (R ends at {adaptive['final_R']}; "
          f"{adaptive['compiles']} compiles total, "
          f"{adaptive['compiles_after_warmup']} after warmup)")
    summary = {
        "baseline_spec": base["spec"],
        "bytes_vs_static_min_R": round(ratio, 3),
        "final_loss_adaptive": adaptive["final_loss"],
        "final_loss_baseline": base["final_loss"],
        "loss_margin": w["loss_margin"],
        "meets_criteria": bool(ratio <= 0.6 and loss_ok
                               and adaptive["compiles_after_warmup"] == 0),
    }
    print(f"# summary: bytes {ratio:.2f}x baseline, loss "
          f"{adaptive['final_loss']:.4f} vs {base['final_loss']:.4f}, "
          f"meets_criteria={summary['meets_criteria']}")
    # keep the JSON readable: baseline keeps its full trajectory (the
    # controller's budget), other static rows just the summary numbers
    for r in static[1:]:
        r.pop("loss_trajectory")
    return {"workload": {**w, "steps": steps}, "static": static,
            "adaptive": adaptive, "summary": summary}


def main(out: str = "BENCH_comm.json", sweep_steps: int = 200,
         smoke: bool = False):
    analytic = []
    analytic_table(analytic)
    sweep = adaptive_sweep(40 if smoke else sweep_steps)
    payload = {
        "protocol": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "host": platform.platform(),
            "device": jax.devices()[0].platform,
            "jax": jax.__version__,
            "smoke": smoke,
        },
        "analytic": analytic,
        "adaptive_sweep": sweep,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short sweep for CI (seconds)")
    ap.add_argument("--out", default="BENCH_comm.json")
    ap.add_argument("--sweep-steps", type=int, default=200)
    args = ap.parse_args()
    main(out=args.out, sweep_steps=args.sweep_steps, smoke=args.smoke)
