"""Communication-cost benchmark: bytes on the SL boundary per training step.

The paper's headline: R x fewer bytes both directions.  Also covers the
beyond-paper int8 wire format (4R x total)."""
from __future__ import annotations

from repro.configs.paper import RESNET50_CIFAR100, VGG16_CIFAR10
from repro.core import codec as codec_lib
from repro.core.bottlenet import BottleNetPPCodec
from repro.core.metrics import comm_report


def main():
    print("# boundary traffic per step (fwd+bwd)")
    print("config,method,R,total_bytes,compression_x")
    for cfg in (VGG16_CIFAR10, RESNET50_CIFAR100):
        B, D = cfg.batch_size, cfg.D
        C, H, W = cfg.cut_shape
        rows = [("vanilla", codec_lib.IdentityCodec(D=D))]
        for R in (2, 4, 8, 16):
            rows.append((f"c3sl", codec_lib.C3SLCodec(R=R, D=D)))
            rows.append((f"c3sl-int8", codec_lib.C3SLCodec(R=R, D=D, quant_bits=8)))
            rows.append((f"bottlenet++", BottleNetPPCodec(R=R, C=C, H=H, W=W)))
        for name, codec in rows:
            r = comm_report(codec, B, D, method=name)
            print(f"{cfg.name},{name},{getattr(codec,'R',1)},{r.total},"
                  f"{r.compression:.2f}")


if __name__ == "__main__":
    main()
