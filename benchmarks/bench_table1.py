"""Paper Table 1 reproduction — analytic columns.

Checks our codec accounting against the paper's printed Number-of-Parameters
and FLOPs columns for every (model, R); flags the two R=2 BottleNet++ rows
where the paper's own numbers deviate from its own Table 2 formula (see
EXPERIMENTS.md).  Accuracy columns are reproduced at laptop scale by
benchmarks/bench_accuracy.py.
"""
from __future__ import annotations

from repro.configs.paper import (PAPER_RS, RESNET50_CIFAR100, TABLE1,
                                 TABLE1_BOTTLENET, VGG16_CIFAR10)
from repro.codecs import BottleNetPPCodec, C3SLCodec


def check_rows():
    rows = []
    for cfg in (VGG16_CIFAR10, RESNET50_CIFAR100):
        C, H, W = cfg.cut_shape
        B = cfg.batch_size
        for R in PAPER_RS:
            c3 = C3SLCodec(R=R, D=cfg.D)
            want_acc, want_p, want_f = TABLE1[(cfg.name, R)]
            got_p = c3.param_count() / 1e3
            got_f = c3.flops(B) / 1e9
            rows.append({
                "config": cfg.name, "method": "c3sl", "R": R,
                "params_k": got_p, "paper_params_k": want_p,
                "params_match": abs(got_p - want_p) / want_p < 0.02,
                "flops_g": got_f, "paper_flops_g": want_f,
                "flops_match": abs(got_f - want_f) / want_f < 0.02,
            })
            bn = BottleNetPPCodec(R=R, C=C, H=H, W=W)
            want_acc, want_p, want_f = TABLE1_BOTTLENET[(cfg.name, R)]
            got_p = bn.param_count() / 1e3
            got_f = bn.flops(B) / 1e9
            rows.append({
                "config": cfg.name, "method": "bottlenet++", "R": R,
                "params_k": got_p, "paper_params_k": want_p,
                "params_match": abs(got_p - want_p) / want_p < 0.02,
                "flops_g": got_f, "paper_flops_g": want_f,
                "flops_match": abs(got_f - want_f) / want_f < 0.02,
            })
    return rows


def main():
    print("# Table 1 (params/FLOPs columns): ours vs paper")
    print("config,method,R,params_k,paper_params_k,params_match,"
          "flops_g,paper_flops_g,flops_match")
    n_match = n_total = 0
    for r in check_rows():
        print(f"{r['config']},{r['method']},{r['R']},{r['params_k']:.1f},"
              f"{r['paper_params_k']},{r['params_match']},{r['flops_g']:.2f},"
              f"{r['paper_flops_g']},{r['flops_match']}")
        n_match += int(r["params_match"]) + int(r["flops_match"])
        n_total += 2
    print(f"# matched {n_match}/{n_total} cells "
          f"(known paper-internal inconsistency: BottleNet++ R=2 rows)")


if __name__ == "__main__":
    main()
