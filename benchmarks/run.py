"""Benchmark entrypoint: one section per paper table + system benches.

    PYTHONPATH=src python -m benchmarks.run [--fast]

--fast skips the accuracy-trend training runs (several minutes on CPU).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--accuracy-steps", type=int, default=300)
    args = ap.parse_args()

    from benchmarks import (bench_accuracy, bench_codec_latency, bench_comm,
                            bench_roofline, bench_serving, bench_table1,
                            bench_table2)

    sections = [
        ("table2_formulas", bench_table2.main),
        ("table1_columns", bench_table1.main),
        # --fast shortens the adaptive-R sweep; both write BENCH_comm.json
        ("comm_bytes", lambda: bench_comm.main(smoke=args.fast)),
        ("codec_latency", bench_codec_latency.main),
        # --fast runs the smoke variant (seconds); both write BENCH_serving.json
        ("serving_throughput", lambda: bench_serving.main(smoke=args.fast)),
        # backend + paged-read sweeps; both write BENCH_roofline.json
        ("roofline_sweeps", lambda: bench_roofline.main(smoke=args.fast)),
    ]
    for name, fn in sections:
        print(f"\n==== {name} ====", flush=True)
        t0 = time.time()
        fn()
        print(f"# section {name}: {time.time()-t0:.1f}s", flush=True)

    print("\n==== roofline (from dry-run artifacts, if present) ====", flush=True)
    try:
        bench_roofline.aggregate()
    except Exception as e:  # dry-run artifacts may not exist yet
        print(f"# roofline aggregation skipped: {e}")

    if not args.fast:
        print("\n==== table1_accuracy_trend (laptop-scale) ====", flush=True)
        bench_accuracy.main(steps=args.accuracy_steps)


if __name__ == "__main__":
    main()
