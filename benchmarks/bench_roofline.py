"""Roofline sweeps: HRR backend latency + paged-read kernel-vs-gather.

    PYTHONPATH=src python -m benchmarks.bench_roofline [--smoke] [--out F]

Two sweeps, both recorded in ``BENCH_roofline.json`` (see
benchmarks/README.md for the protocol and column definitions):

* ``circconv`` — the C3-SL codec round-trip across execution backends
  (fft | direct | pallas) and feature widths, with the ESTIMATED minimal
  HBM bytes each round-trip moves next to the measured wall time.
* ``paged_read`` — one fused decode step with the paged KV cache read as
  a contiguous gather vs the in-kernel page-table walk
  (``kv_read="gather" | "kernel"``), tokens/s plus the estimated cache
  bytes each read path moves per step.

Execution-mode honesty: every row carries the EFFECTIVE execution mode
(``Codec.execution_mode()`` / engine ``stats["kv_read_execution_mode"]``),
and :func:`record` REFUSES to record an interpret-mode row labeled
``backend=pallas`` / ``kv_read=kernel`` unless the row explicitly tags
``interpret: true`` — CPU-interpreted kernel timings must never pose as
kernel numbers (the silent-fallback bug class this tier fixes).

``aggregate()`` is the original dry-run §Roofline table formatter, kept
under its own name (benchmarks.run calls it separately).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import platform
import time

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


# ---------------------------------------------------------------------------
# execution-mode honesty guard
# ---------------------------------------------------------------------------

def record(results: list, row: dict) -> dict:
    """Append ``row`` to ``results`` — unless it lies about how it ran.

    A row claiming a Pallas kernel (``backend`` starting with "pallas", or
    ``kv_read == "kernel"``) must carry its effective ``execution_mode``;
    if that mode is interpret (CPU emulation), the row must ALSO carry an
    explicit ``interpret: true`` tag, or it is refused.  Interpret numbers
    are allowed on the record — correctness CI wants them — but only
    labeled as what they are.
    """
    claims_kernel = (str(row.get("backend", "")).startswith("pallas")
                     or row.get("kv_read") == "kernel")
    if claims_kernel:
        mode = row.get("execution_mode")
        if mode is None:
            raise ValueError(
                f"refusing to record kernel-claiming row {row!r} without an "
                "execution_mode tag (Codec.execution_mode() / engine "
                "stats['kv_read_execution_mode'])")
        if "interpret" in mode and not row.get("interpret", False):
            raise ValueError(
                f"refusing to record row {row!r}: execution_mode={mode!r} "
                "is CPU-interpreted, which must not pose as a kernel "
                "measurement — tag the row with interpret=True to record "
                "it as what it is")
    results.append(row)
    return row


def _timeit(fn, *args, iters=5):
    import jax
    jax.block_until_ready(fn(*args))          # compile outside the clock
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


# ---------------------------------------------------------------------------
# sweep 1: circconv backends (fft vs direct vs pallas)
# ---------------------------------------------------------------------------

def sweep_circconv(results: list, smoke: bool) -> None:
    import jax
    from repro.codecs import build

    B, R = (16, 4) if smoke else (64, 4)
    iters = 2 if smoke else 5
    Ds = [256] if smoke else [256, 1024, 4096]
    print("# circconv round-trip: backend sweep")
    print("backend,D,execution_mode,us_per_call,bytes_moved")
    for D in Ds:
        for backend in ("fft", "direct", "pallas"):
            c = build(f"c3sl:R={R},D={D},backend={backend}")
            mode = c.execution_mode()
            p = c.init(jax.random.PRNGKey(1))
            Z = jax.random.normal(jax.random.PRNGKey(0), (B, D))
            f = jax.jit(lambda z: c.decode(p, c.encode(p, z)))
            s = _timeit(f, Z, iters=iters)
            # minimal HBM traffic of one round-trip: read Z, write payload,
            # read payload, write Zhat, plus the keys twice (f32)
            G = B // R
            bytes_moved = 4 * (B * D + G * D + G * D + B * D + 2 * R * D)
            row = {"bench": "circconv", "backend": backend, "D": D, "B": B,
                   "R": R, "execution_mode": mode,
                   "us_per_call": round(s * 1e6, 1),
                   "bytes_moved": bytes_moved}
            if "interpret" in mode:
                row["interpret"] = True      # honest tag: CPU emulation
            record(results, row)
            print(f"{backend},{D},{mode},{row['us_per_call']},{bytes_moved}",
                  flush=True)


# ---------------------------------------------------------------------------
# sweep 2: paged decode read — in-kernel page walk vs contiguous gather
# ---------------------------------------------------------------------------

def _paged_setup(smoke: bool):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.base import get_config, reduced
    from repro.models import lm as lm_lib
    from repro.models.paging import PagedLayout

    if smoke:
        cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                      d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                      head_dim=32)
        B, T, ps = 4, 32, 8
    else:
        cfg = reduced(get_config("deepseek-7b"), num_layers=4, d_model=256,
                      d_ff=512, vocab_size=512, num_heads=8, num_kv_heads=4,
                      head_dim=32)
        B, T, ps = 8, 256, 16
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    pps = -(-T // ps)
    layout = PagedLayout(ps, T, B * pps)
    cache = lm_lib.init_decode_cache(params, cfg, B, T, paged=layout)
    rng = np.random.RandomState(0)
    cache["pages"] = jnp.asarray(
        rng.permutation(B * pps).astype(np.int32).reshape(B, pps))
    return cfg, params, layout, cache, B, T


def sweep_paged_read(results: list, smoke: bool) -> None:
    import jax
    import jax.numpy as jnp
    from repro.kernels import circconv
    from repro.models import lm as lm_lib

    cfg, params, layout, cache, B, T = _paged_setup(smoke)
    iters = 2 if smoke else 5
    toks = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.full((B,), T - 1, jnp.int32)      # worst case: full-length read
    n_attn = sum(k == "attn" for layer in cfg.block_pattern for k in layer)
    n_attn *= cfg.num_superblocks
    kv_dtype = 1 if cfg.kv_cache_quant else 4
    pool_bytes = B * T * cfg.num_kv_heads * cfg.head_dim_ * kv_dtype
    print("# paged decode read: kernel vs gather "
          f"(B={B} T={T} layers={n_attn})")
    print("kv_read,execution_mode,tokens_per_s,bytes_moved_per_step")
    for kv_read in ("gather", "kernel"):
        f = jax.jit(lambda c, kr=kv_read: lm_lib.decode_step(
            params, c, toks, pos, cfg, paged=layout, kv_read=kr)[0])
        s = _timeit(f, cache, iters=iters)
        mode = (circconv.execution_mode() if kv_read == "kernel"
                else "gather")
        # per step, per attn layer, k + v: gather reads the table-covered
        # pool, WRITES the contiguous view, and the attention re-reads it
        # (3x); the kernel streams the pages once (1x)
        factor = 1 if kv_read == "kernel" else 3
        bytes_moved = factor * 2 * pool_bytes * n_attn
        row = {"bench": "paged_read", "kv_read": kv_read, "B": B, "T": T,
               "execution_mode": mode,
               "tokens_per_s": round(B / s, 1),
               "bytes_moved_per_step": bytes_moved}
        if "interpret" in mode:
            row["interpret"] = True          # honest tag: CPU emulation
        record(results, row)
        print(f"{kv_read},{mode},{row['tokens_per_s']},{bytes_moved}",
              flush=True)


def main(smoke: bool = False, out: str = "BENCH_roofline.json"):
    import jax
    results: list[dict] = []
    sweep_circconv(results, smoke)
    sweep_paged_read(results, smoke)
    payload = {
        "protocol": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "host": platform.platform(),
            "device": jax.devices()[0].platform,
            "jax": jax.__version__,
            "smoke": smoke,
        },
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return payload


# ---------------------------------------------------------------------------
# dry-run artifact aggregation (the original §Roofline table)
# ---------------------------------------------------------------------------

def load(mesh="single", tag="baseline"):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*_{mesh}_{tag}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    return rows


def fmt_row(r):
    if r["status"] != "ok":
        return (f"{r['arch']},{r['shape']},{r['status']},,,,,,,")
    t = r["roofline"]
    return (f"{r['arch']},{r['shape']},ok,"
            f"{t['compute_s']:.3f},{t['memory_s']:.3f},{t['collective_s']:.3f},"
            f"{r['dominant'].replace('_s','')},"
            f"{r['useful_flops_ratio']:.3f},"
            f"{r['per_device']['peak_bytes']/2**30:.2f},"
            f"{r.get('num_microbatches', 1)}")


def aggregate(mesh="single", tag="baseline"):
    rows = load(mesh, tag)
    print(f"# roofline table ({mesh} mesh, tag={tag}); terms in seconds/step")
    print("arch,shape,status,compute_s,memory_s,collective_s,dominant,"
          "useful_flops_ratio,peak_GiB,microbatches")
    for r in rows:
        print(fmt_row(r))
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    print(f"# {n_ok} ok, {n_skip} skipped (documented), {len(rows)} total")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_roofline.json")
    ap.add_argument("--aggregate", action="store_true",
                    help="print the dry-run artifact table instead of "
                         "running the sweeps")
    args = ap.parse_args()
    if args.aggregate:
        aggregate()
    else:
        main(smoke=args.smoke, out=args.out)
