"""Aggregate the dry-run JSONs into the EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh="single", tag="baseline"):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*_{mesh}_{tag}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    return rows


def fmt_row(r):
    if r["status"] != "ok":
        return (f"{r['arch']},{r['shape']},{r['status']},,,,,,,")
    t = r["roofline"]
    return (f"{r['arch']},{r['shape']},ok,"
            f"{t['compute_s']:.3f},{t['memory_s']:.3f},{t['collective_s']:.3f},"
            f"{r['dominant'].replace('_s','')},"
            f"{r['useful_flops_ratio']:.3f},"
            f"{r['per_device']['peak_bytes']/2**30:.2f},"
            f"{r.get('num_microbatches', 1)}")


def main(mesh="single", tag="baseline"):
    rows = load(mesh, tag)
    print(f"# roofline table ({mesh} mesh, tag={tag}); terms in seconds/step")
    print("arch,shape,status,compute_s,memory_s,collective_s,dominant,"
          "useful_flops_ratio,peak_GiB,microbatches")
    for r in rows:
        print(fmt_row(r))
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    print(f"# {n_ok} ok, {n_skip} skipped (documented), {len(rows)} total")


if __name__ == "__main__":
    import sys
    main(*(sys.argv[1:] or []))
