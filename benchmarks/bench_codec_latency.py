"""Codec latency microbenchmark (CPU wall-time; TPU numbers come from the
roofline analysis since this container has no TPU).

Compares the three C3-SL execution backends (fft / direct / pallas-interpret)
and BottleNet++ at the paper's shapes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.codecs import build


def timeit(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main():
    B, R = 64, 4
    print("# codec round-trip latency (CPU reference)")
    print("name,us_per_call,derived")
    # O(D log D) fft backend at the paper's full D; O(D^2) backends at D=1024
    # (1-core CPU container; the TPU story is in the roofline analysis)
    for backend, D, iters in (("fft", 4096, 10), ("direct", 1024, 3),
                              ("pallas", 1024, 3)):
        Z = jax.random.normal(jax.random.PRNGKey(0), (B, D))
        c = build(f"c3sl:R={R},D={D},backend={backend}")
        p = c.init(jax.random.PRNGKey(1))
        f = jax.jit(lambda z: c.decode(p, c.encode(p, z)))
        us = timeit(f, Z, iters=iters)
        print(f"c3sl_{backend},{us:.0f},B={B} D={D} R={R}", flush=True)
    Z = jax.random.normal(jax.random.PRNGKey(0), (B, 4096))
    bn = build(f"bnpp:R={R},C=1024,H=2,W=2")
    pbn = bn.init(jax.random.PRNGKey(2))
    Z4 = Z.reshape(B, 1024, 2, 2)
    f = jax.jit(lambda z: bn.decode(pbn, bn.encode(pbn, z)))
    us = timeit(f, Z4)
    print(f"bottlenetpp,{us:.0f},B={B} C=1024 HxW=2x2 R={R}")


if __name__ == "__main__":
    main()
