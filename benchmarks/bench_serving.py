"""Serving throughput benchmark: chunked prefill + device-resident stepping
vs the prefill-as-decode baseline, and paged vs contiguous KV cache.

Measures end-to-end tokens/s of the continuous-batching engine on a
prompt-heavy and a decode-heavy request mix, at several codec specs, in
both engine modes, and writes ``BENCH_serving.json`` so later perf PRs
have a recorded trajectory to beat.  A third, mixed long/short-prompt
workload compares the paged KV cache (oversubscribed page pool) against
the contiguous per-slot strips on tokens/s, mean/max time-to-first-token,
and peak cache bytes — with and without prefill/decode interleaving.
A fourth, multi-tenant Poisson workload (a standard tenant's short
priority-0 stream plus a premium tenant's long priority-1 requests over
an oversubscribed page pool) compares slot preemption against FIFO
blocking on per-tenant TTFT p50/p99 and time-weighted pool utilization.
All timed sections run identically-seeded repeats and report the
min/mean/max tokens/s spread (full mode: 3 repeats; smoke: 1).
See benchmarks/README.md for the protocol and the JSON schema.

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

MIXES = {
    # name: (prompt_len, max_new_tokens) — prompt-heavy is where chunked
    # prefill pays off (O(L/C) dispatches instead of O(L)); decode-heavy
    # isolates the device-resident stepping + batched EOS fetches.
    "prompt_heavy": (64, 8),
    "decode_heavy": (8, 48),
}
SMOKE_MIXES = {"prompt_heavy": (16, 2), "decode_heavy": (4, 6)}

CODECS = ["none", "c3sl:R=4", "c3sl:R=4|int8"]
SMOKE_CODECS = ["none", "c3sl:R=2"]

# Mixed long/short workload for the paged-vs-contiguous comparison: requests
# alternate the two prompt lengths, so under the contiguous layout every
# short request still reserves a full max_len strip while the paged pool
# (sized below slots * max_len) only holds what each request can touch.
MIXED = {"long": (96, 16), "short": (8, 16), "n_each": 4}
SMOKE_MIXED = {"long": (12, 2), "short": (3, 2), "n_each": 2}

# Multi-tenant Poisson workload: a "standard" tenant streams short
# priority-0 requests while a "premium" tenant occasionally submits a
# long priority-1 request whose page footprint doesn't fit the
# oversubscribed pool alongside a full complement of standard slots.
# Under FIFO the premium head blocks admission while the pool drains;
# with preemption it evicts standard slots and is admitted immediately.
# Arrival times are in TICK units (deterministic given the seed), not
# wall-clock: per tenant, inter-arrival gaps ~ Exp(mean_gap) ticks.
# max_new spans several sync_every decode windows so requests stay
# resident across ticks — a request that finishes inside one tick can
# neither be observed occupying the pool nor be preempted.
MULTI_TENANT = {
    "standard": {"prompt_len": 8, "max_new": 32, "n": 16, "mean_gap": 3.0,
                 "priority": 0},
    "premium": {"prompt_len": 96, "max_new": 24, "n": 3, "mean_gap": 25.0,
                "priority": 1},
}
SMOKE_MULTI_TENANT = {
    "standard": {"prompt_len": 4, "max_new": 12, "n": 4, "mean_gap": 2.0,
                 "priority": 0},
    "premium": {"prompt_len": 20, "max_new": 8, "n": 1, "mean_gap": 8.0,
                "priority": 1},
}

# Speculative decoding sweep: decode-heavy on purpose — verify rounds ship
# ZERO forward bytes (the server replays the bottom stack from known token
# ids), so the per-generated-token wire cost is what k amortizes, and
# prompt prefill (unchanged by speculation) must not drown the signal.
# The sweep pins the criterion on the "copy" draft head (client-side, no
# feedback payload) and adds one "tied" row at k=4 to record the
# draft-codec feedback channel's acceptance/wire tradeoff.
SPEC_KS = (1, 2, 4, 8)
SPEC_MIX = {"prompt_len": 8, "max_new": 48}
SMOKE_SPEC_MIX = {"prompt_len": 4, "max_new": 24}
SPEC_CODECS = ["none", "c3sl:R=4|int8"]
SMOKE_SPEC_CODECS = ["none", "c3sl:R=2|int8"]


def _agg_reps(rows: list[dict]) -> dict:
    """Collapse repeated runs (identical pinned seeds -> identical token
    streams) into one row: mean wall/throughput plus min/max spread."""
    tok = {r["generated_tokens"] for r in rows}
    assert len(tok) == 1, f"pinned seeds but divergent outputs: {tok}"
    tps = [r["tokens_per_s"] for r in rows]
    out = dict(rows[0])
    out["wall_s"] = round(sum(r["wall_s"] for r in rows) / len(rows), 4)
    out["tokens_per_s"] = round(sum(tps) / len(tps), 1)
    out["tokens_per_s_min"] = min(tps)
    out["tokens_per_s_max"] = max(tps)
    out["repeats"] = len(rows)
    return out


def _build(smoke: bool):
    from repro.configs.base import get_config, reduced
    from repro.models import lm as lm_lib
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run_once(cfg, params, *, mode, codec, prompt_len, max_new, requests,
              num_slots, max_len, chunk_size, sync_every, seed=0, reps=1):
    from repro.serving.engine import BatchedEngine, Request
    eng = BatchedEngine(params, cfg, num_slots=num_slots, max_len=max_len,
                        codec=codec, greedy=True, seed=seed,
                        prefill_mode=mode, chunk_size=chunk_size,
                        sync_every=sync_every)

    def batch(n, uid0, rng):
        return [Request(uid=uid0 + i,
                        prompt=list(map(int, rng.randint(1, cfg.vocab_size,
                                                         prompt_len))),
                        max_new_tokens=max_new) for i in range(n)]

    # warmup: compile every program (prefill, fused step, reset) off the clock
    for r in batch(min(2, requests), 10_000, np.random.RandomState(seed + 99)):
        eng.submit(r)
    eng.run()
    eng.finished.clear()

    rows = []
    for rep in range(reps):
        # identical pinned seed every rep: same prompts, same token streams
        reqs = batch(requests, rep * 100_000,
                     np.random.RandomState(seed + 1))
        for r in reqs:
            eng.submit(r)
        t0 = time.time()
        done = list(eng.run())      # copy: run() returns eng.finished itself
        wall = time.time() - t0
        assert len(done) == requests, (len(done), requests)
        eng.finished.clear()
        generated = sum(len(r.out) for r in done)
        total = generated + requests * prompt_len
        rows.append({"wall_s": round(wall, 4),
                     "prompt_tokens": requests * prompt_len,
                     "generated_tokens": generated,
                     "tokens_per_s": round(total / wall, 1)})
    return _agg_reps(rows)


def _run_mixed(cfg, params, *, kv_layout, interleave, mixed, num_slots,
               max_len, page_size, num_pages, chunk_size, sync_every, seed=0,
               reps=1):
    """Mixed long/short runs; returns throughput, TTFT, and cache bytes
    aggregated over ``reps`` identically-seeded repeats."""
    from repro.serving.engine import BatchedEngine, Request
    eng = BatchedEngine(params, cfg, num_slots=num_slots, max_len=max_len,
                        greedy=True, seed=seed, prefill_mode="chunked",
                        chunk_size=chunk_size, sync_every=sync_every,
                        kv_layout=kv_layout, page_size=page_size,
                        num_pages=num_pages if kv_layout == "paged" else None,
                        interleave=interleave)
    (llen, lnew), (slen, snew) = mixed["long"], mixed["short"]

    def batch(uid0, rng):
        reqs = []
        for i in range(mixed["n_each"]):
            for ln, mn in ((llen, lnew), (slen, snew)):
                reqs.append(Request(
                    uid=uid0 + len(reqs),
                    prompt=list(map(int, rng.randint(1, cfg.vocab_size, ln))),
                    max_new_tokens=mn))
        return reqs

    # warmup: compile off the clock
    for r in batch(10_000, np.random.RandomState(seed + 99))[:2]:
        eng.submit(r)
    eng.run()
    eng.finished.clear()

    rows = []
    for rep in range(reps):
        eng.stats = {k: 0 for k in eng.stats}    # count this rep only
        reqs = batch(rep * 100_000, np.random.RandomState(seed + 1))
        t0 = time.time()
        for r in reqs:
            eng.submit(r)
        done = list(eng.run())      # copy: run() returns eng.finished itself
        wall = time.time() - t0
        assert len(done) == len(reqs), (len(done), len(reqs))
        eng.finished.clear()
        generated = sum(len(r.out) for r in done)
        prompt_tokens = sum(len(r.prompt) for r in reqs)
        ttfts = [r.t_first - r.t_submit for r in done
                 if r.t_first is not None]
        rows.append({"wall_s": round(wall, 4),
                     "prompt_tokens": prompt_tokens,
                     "generated_tokens": generated,
                     "tokens_per_s": round((prompt_tokens + generated) / wall,
                                           1),
                     "ttft_mean_s": round(sum(ttfts) / len(ttfts), 4),
                     "ttft_max_s": round(max(ttfts), 4),
                     "peak_cache_bytes": eng.cache_bytes,
                     "dispatches": eng.stats["dispatches"]})
    out = _agg_reps(rows)
    out["ttft_mean_s"] = round(
        sum(r["ttft_mean_s"] for r in rows) / len(rows), 4)
    out["ttft_max_s"] = round(max(r["ttft_max_s"] for r in rows), 4)
    return out


def _run_multi_tenant(cfg, params, *, tenants, preemption, num_slots,
                      max_len, page_size, num_pages, chunk_size, sync_every,
                      seed=0):
    """Drive the engine tick-by-tick under a Poisson (per-tenant) arrival
    schedule; returns per-tenant TTFT percentiles and the time-weighted
    page-pool utilization.  The arrival schedule is identical for every
    ``preemption`` setting (same seed -> same ticks, prompts, priorities)."""
    from repro.serving.engine import BatchedEngine, Request
    eng = BatchedEngine(params, cfg, num_slots=num_slots, max_len=max_len,
                        greedy=True, seed=seed, prefill_mode="chunked",
                        chunk_size=chunk_size, sync_every=sync_every,
                        kv_layout="paged", page_size=page_size,
                        num_pages=num_pages, preemption=preemption)

    rng = np.random.RandomState(seed + 1)
    schedule = []        # (arrival_tick, tenant, prompt, max_new, priority)
    for name in sorted(tenants):
        t = tenants[name]
        ticks = np.cumsum(rng.exponential(t["mean_gap"], t["n"]))
        for at in ticks:
            prompt = list(map(int, rng.randint(1, cfg.vocab_size,
                                               t["prompt_len"])))
            schedule.append((float(at), name, prompt, t["max_new"],
                             t["priority"]))
    schedule.sort(key=lambda s: s[0])

    # warmup: compile prefill/decode/reset programs off the clock (one
    # request per tenant shape; the preemption path reuses the same
    # programs, so nothing compiles mid-measurement)
    for uid, name in enumerate(sorted(tenants)):
        t = tenants[name]
        eng.submit(Request(uid=10_000 + uid,
                           prompt=[1] * t["prompt_len"],
                           max_new_tokens=t["max_new"]))
    eng.run()
    eng.finished.clear()
    eng.stats = {k: 0 for k in eng.stats}

    tenant_of = {}
    pending = [(at, name, Request(uid=uid, prompt=prompt, max_new_tokens=mn,
                                  priority=pr))
               for uid, (at, name, prompt, mn, pr) in enumerate(schedule)]
    for _, name, req in pending:
        tenant_of[req.uid] = name
    total = eng.paged.num_pages
    util_num = util_den = 0.0
    tick = done = 0
    t_start = time.time()
    while pending or eng.queue or eng.active:
        while pending and pending[0][0] <= tick:
            eng.submit(pending.pop(0)[2])
        t0 = time.time()
        moved = eng.tick()
        dt = time.time() - t0
        if moved:
            # time-weighted occupancy: what fraction of the page pool did
            # useful work while the engine was busy this tick
            util_num += dt * eng.pool_accounting()["in_use"] / total
            util_den += dt
        tick += 1
    wall = time.time() - t_start
    finished, eng.finished = list(eng.finished), []
    assert len(finished) == len(schedule), (len(finished), len(schedule))

    per_tenant = {}
    for req in finished:
        per_tenant.setdefault(tenant_of[req.uid], []).append(req)
    tenant_rows = {}
    for name, reqs in sorted(per_tenant.items()):
        ttfts = [r.t_first - r.t_submit for r in reqs
                 if r.t_first is not None]
        tenant_rows[name] = {
            "requests": len(reqs),
            "priority": tenants[name]["priority"],
            "generated_tokens": sum(len(r.out) for r in reqs),
            "evictions": sum(r.evictions for r in reqs),
            "ttft_p50_s": round(float(np.percentile(ttfts, 50)), 4),
            "ttft_p99_s": round(float(np.percentile(ttfts, 99)), 4),
            "ttft_max_s": round(max(ttfts), 4)}
    generated = sum(len(r.out) for r in finished)
    prompt_tokens = sum(len(r.prompt) for r in finished)
    return {"wall_s": round(wall, 4),
            "prompt_tokens": prompt_tokens,
            "generated_tokens": generated,
            "tokens_per_s": round((prompt_tokens + generated) / wall, 1),
            "pool_utilization": round(util_num / max(util_den, 1e-9), 3),
            "evictions": eng.stats["evictions"],
            "eos_early_exits": eng.stats["eos_early_exits"],
            "ticks": tick,
            "tenants": tenant_rows}


def _run_spec(cfg, params, *, codec, spec_decode, prompt_len, max_new,
              requests, num_slots, max_len, chunk_size, sync_every, seed=0):
    """One speculative (or k=1 vanilla) run with exact wire accounting:
    stats are zeroed after warmup so the measured totals cover exactly the
    timed requests, then the engine's per-channel counters are
    cross-checked against an independent recomputation."""
    from repro.serving.engine import BatchedEngine, Request
    eng = BatchedEngine(params, cfg, num_slots=num_slots, max_len=max_len,
                        codec=codec, greedy=True, seed=seed,
                        prefill_mode="chunked", chunk_size=chunk_size,
                        sync_every=sync_every, spec_decode=spec_decode)

    def batch(n, uid0, rng):
        return [Request(uid=uid0 + i,
                        prompt=list(map(int, rng.randint(1, cfg.vocab_size,
                                                         prompt_len))),
                        max_new_tokens=max_new) for i in range(n)]

    for r in batch(min(2, requests), 10_000, np.random.RandomState(seed + 99)):
        eng.submit(r)
    eng.run()
    eng.finished.clear()
    eng.stats = {k: 0 for k in eng.stats}
    eng.r_served.clear()
    eng.k_served.clear()
    eng._tokens_decoded = 0

    reqs = batch(requests, 0, np.random.RandomState(seed + 1))
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    done = list(eng.run())
    wall = time.time() - t0
    assert len(done) == requests, (len(done), requests)
    eng.finished.clear()
    done.sort(key=lambda r: r.uid)
    outputs = [r.out for r in done]
    generated = sum(len(o) for o in outputs)

    wpt = eng.wire_per_token()
    # satellite cross-check: the per-token metric must be consistent with
    # the engine's raw channel counters AND with an independent
    # recomputation from the served round schedule
    assert wpt["wire_bytes_fwd"] == eng.stats["payload_wire_bytes"], wpt
    assert wpt["generated_tokens"] == generated, (wpt, generated)
    if spec_decode is not None:
        draft_expect = sum(rounds * eng._draft_round_wire_bytes(kk)
                           for kk, rounds in eng.k_served.items())
        assert wpt["wire_bytes_draft"] == draft_expect, \
            (wpt, dict(eng.k_served))
    else:
        assert wpt["wire_bytes_draft"] == 0, wpt

    acc = eng.stats["spec_accepted"]
    rej = eng.stats["spec_rejected"]
    row = {"wall_s": round(wall, 4),
           "prompt_tokens": requests * prompt_len,
           "generated_tokens": generated,
           "tokens_per_s": round((generated + requests * prompt_len) / wall,
                                 1),
           "spec_rounds": eng.stats["spec_rounds"],
           "spec_rollbacks": eng.stats["spec_rollbacks"],
           "acceptance_rate": (round(acc / (acc + rej), 3)
                               if acc + rej else None),
           "wire_bytes_fwd": wpt["wire_bytes_fwd"],
           "wire_bytes_draft": wpt["wire_bytes_draft"],
           "wire_bytes_per_token": round(wpt["wire_bytes_per_token"], 2)}
    return row, outputs


def bench_spec(cfg, params, smoke, chunk_size, sync_every, results):
    """Speculative decoding: k-sweep per codec, greedy outputs pinned
    bit-identical to the k=1 vanilla run, wire bytes per generated token
    vs the vanilla baseline (the ISSUE criterion: <= 0.5x at k=4 on the
    codec workload)."""
    from repro.serving.spec import SpecConfig
    mix = SMOKE_SPEC_MIX if smoke else SPEC_MIX
    codecs = SMOKE_SPEC_CODECS if smoke else SPEC_CODECS
    requests = 2 if smoke else 8
    num_slots = 2 if smoke else 4
    max_len = 32 if smoke else 128
    common = dict(prompt_len=mix["prompt_len"], max_new=mix["max_new"],
                  requests=requests, num_slots=num_slots, max_len=max_len,
                  chunk_size=chunk_size, sync_every=sync_every)
    for codec in codecs:
        ref_out = None
        base_wpt = None
        runs = [(k, "copy", None) for k in SPEC_KS]
        if codec != "none":
            # the tied head pays the draft-codec feedback payload in
            # exchange for model-informed drafts — recorded, not pinned
            runs.append((4, "tied", codec))
        for k, head, draft in runs:
            spec_cfg = (None if k == 1 else
                        SpecConfig(k=k, draft=draft, draft_head=head))
            r, outputs = _run_spec(cfg, params, codec=codec,
                                   spec_decode=spec_cfg, **common)
            if ref_out is None:
                ref_out = outputs
            else:
                assert outputs == ref_out, (
                    f"speculative outputs diverged from vanilla decode at "
                    f"codec={codec} k={k} head={head}")
            row = {"mix": "spec_decode", "codec": codec, "mode": "chunked",
                   "spec_k": k, "draft_head": head if k > 1 else None,
                   "draft_codec": draft, "chunk_size": chunk_size,
                   "sync_every": sync_every, "requests": requests,
                   "num_slots": num_slots, **r}
            if k == 1:
                base_wpt = r["wire_bytes_per_token"]
            elif base_wpt:
                ratio = round(r["wire_bytes_per_token"] / base_wpt, 3)
                row["wire_per_token_vs_k1"] = ratio
                if k == 4 and head == "copy" and codec != "none":
                    row["meets_criteria"] = ratio <= 0.5
            results.append(row)
            rate = r["acceptance_rate"]
            print(f"spec_decode codec={codec:16s} k={k} head={head:4s} "
                  f"{r['tokens_per_s']:8.1f} tok/s  "
                  f"accept {rate if rate is not None else '-':>5}  "
                  f"wire {r['wire_bytes_per_token']:7.2f} B/token"
                  + (f"  ({row['wire_per_token_vs_k1']:.3f}x vs k=1)"
                     if "wire_per_token_vs_k1" in row else ""), flush=True)
    return results


def bench_multi_tenant(cfg, params, smoke, chunk_size, sync_every, results):
    """Preemption on vs off under the oversubscribed multi-tenant mix."""
    tenants = SMOKE_MULTI_TENANT if smoke else MULTI_TENANT
    num_slots = 2 if smoke else 4
    max_len = 32 if smoke else 128
    page_size = 8 if smoke else 16
    # pool sized so a full complement of standard slots + one premium
    # request oversubscribes it: premium needs pages the standards hold
    num_pages = 4 if smoke else 10
    base = None
    for preemption in (False, True):
        r = _run_multi_tenant(cfg, params, tenants=tenants,
                              preemption=preemption, num_slots=num_slots,
                              max_len=max_len, page_size=page_size,
                              num_pages=num_pages, chunk_size=chunk_size,
                              sync_every=sync_every)
        row = {"mix": "multi_tenant", "codec": "none", "mode": "chunked",
               "kv_layout": "paged", "preemption": preemption,
               "page_size": page_size, "num_pages": num_pages,
               "chunk_size": chunk_size, "sync_every": sync_every,
               "requests": sum(t["n"] for t in tenants.values()),
               "num_slots": num_slots, **r}
        if base is None:
            base = r
        else:
            row["utilization_vs_fifo"] = round(
                r["pool_utilization"] / max(base["pool_utilization"], 1e-9),
                2)
            prem = [n for n, t in tenants.items() if t["priority"] > 0][0]
            row["premium_ttft_p99_vs_fifo"] = round(
                r["tenants"][prem]["ttft_p99_s"]
                / max(base["tenants"][prem]["ttft_p99_s"], 1e-9), 3)
        results.append(row)
        for name, t in r["tenants"].items():
            print(f"multi_tenant preempt={str(preemption):5s} "
                  f"{name:9s} ttft p50 {t['ttft_p50_s']*1e3:8.1f}ms "
                  f"p99 {t['ttft_p99_s']*1e3:8.1f}ms "
                  f"evictions {t['evictions']}", flush=True)
        print(f"multi_tenant preempt={str(preemption):5s} pool util "
              f"{r['pool_utilization']:.3f} "
              f"({r['tokens_per_s']:.1f} tok/s, "
              f"{r['evictions']} evictions)", flush=True)
    return results


def bench_mixed(cfg, params, smoke, chunk_size, sync_every, results, reps=1):
    """Paged vs contiguous (and the interleave knob) on the mixed workload."""
    mixed = SMOKE_MIXED if smoke else MIXED
    num_slots = 2 if smoke else 4
    max_len = 32 if smoke else 128
    page_size = 8 if smoke else 16
    # pool sized to the worst-case CONCURRENT reservation of the alternating
    # admission order (full: 2 long + 2 short = 7+7+2+2 pages), well under
    # the contiguous equivalent of slots * max_len/page_size pages
    num_pages = 3 if smoke else 18
    base = None
    for kv_layout, interleave in (("contiguous", 0), ("paged", 0),
                                  ("paged", 2)):
        # identically-seeded repeats (full mode): wall-clock on shared CPU
        # runners is noisy and the layouts execute identical token streams,
        # so report the spread (min/mean/max), not a lucky best-of
        r = _run_mixed(cfg, params, kv_layout=kv_layout,
                       interleave=interleave, mixed=mixed,
                       num_slots=num_slots, max_len=max_len,
                       page_size=page_size, num_pages=num_pages,
                       chunk_size=chunk_size, sync_every=sync_every,
                       reps=reps)
        row = {"mix": "mixed_long_short", "codec": "none", "mode": "chunked",
               "kv_layout": kv_layout, "interleave": interleave,
               "page_size": page_size if kv_layout == "paged" else None,
               "num_pages": num_pages if kv_layout == "paged" else None,
               "chunk_size": chunk_size, "sync_every": sync_every,
               "requests": 2 * mixed["n_each"], "num_slots": num_slots, **r}
        if base is None:
            base = r
        else:
            row["cache_bytes_vs_contiguous"] = round(
                r["peak_cache_bytes"] / base["peak_cache_bytes"], 3)
            row["speedup_vs_contiguous"] = round(
                r["tokens_per_s"] / base["tokens_per_s"], 2)
        results.append(row)
        print(f"mixed_long_short kv={kv_layout:10s} il={interleave} "
              f"{r['tokens_per_s']:8.1f} tok/s  ttft {r['ttft_mean_s']*1e3:7.1f}ms "
              f"(max {r['ttft_max_s']*1e3:7.1f}ms)  "
              f"cache {r['peak_cache_bytes']/1e6:6.2f}MB", flush=True)
    return results


def main(smoke: bool = False, out: str = "BENCH_serving.json",
         chunk_size: int = 16):
    cfg, params = _build(smoke)
    mixes = SMOKE_MIXES if smoke else MIXES
    codecs = SMOKE_CODECS if smoke else CODECS
    requests = 2 if smoke else 8
    num_slots = 2 if smoke else 4
    max_len = 32 if smoke else 128
    sync_every = 4 if smoke else 8
    # identically-seeded repeats: report the wall-clock spread, not one draw
    reps = 1 if smoke else 3

    results = []
    for mix, (prompt_len, max_new) in mixes.items():
        for spec in codecs:
            per_mode = {}
            for mode in ("decode", "chunked"):
                r = _run_once(cfg, params, mode=mode, codec=spec,
                              prompt_len=prompt_len, max_new=max_new,
                              requests=requests, num_slots=num_slots,
                              max_len=max_len, chunk_size=chunk_size,
                              sync_every=sync_every, reps=reps)
                per_mode[mode] = r
                results.append({"mix": mix, "codec": spec, "mode": mode,
                                "chunk_size": chunk_size if mode == "chunked" else 1,
                                "sync_every": sync_every if mode == "chunked" else 1,
                                "requests": requests, "num_slots": num_slots,
                                **r})
            speedup = (per_mode["chunked"]["tokens_per_s"]
                       / per_mode["decode"]["tokens_per_s"])
            results[-1]["speedup_vs_decode"] = round(speedup, 2)
            print(f"{mix:13s} codec={spec:16s} "
                  f"decode={per_mode['decode']['tokens_per_s']:8.1f} tok/s  "
                  f"chunked={per_mode['chunked']['tokens_per_s']:8.1f} tok/s  "
                  f"({speedup:.2f}x)", flush=True)

    bench_mixed(cfg, params, smoke, chunk_size, sync_every, results,
                reps=reps)
    bench_multi_tenant(cfg, params, smoke, chunk_size, sync_every, results)
    bench_spec(cfg, params, smoke, chunk_size, sync_every, results)

    payload = {
        "protocol": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "host": platform.platform(),
            "device": jax.devices()[0].platform,
            "jax": jax.__version__,
            "smoke": smoke,
        },
        "arch": {"name": cfg.name, "num_layers": cfg.num_layers,
                 "d_model": cfg.d_model, "d_ff": cfg.d_ff,
                 "vocab_size": cfg.vocab_size},
        "mixes": {**{k: {"prompt_len": v[0], "max_new_tokens": v[1]}
                     for k, v in mixes.items()},
                  "spec_decode": {
                      "prompt_len": (SMOKE_SPEC_MIX if smoke
                                     else SPEC_MIX)["prompt_len"],
                      "max_new_tokens": (SMOKE_SPEC_MIX if smoke
                                         else SPEC_MIX)["max_new"]}},
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--chunk-size", type=int, default=16)
    args = ap.parse_args()
    main(smoke=args.smoke, out=args.out, chunk_size=args.chunk_size)
