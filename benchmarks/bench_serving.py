"""Serving throughput benchmark: chunked prefill + device-resident stepping
vs the prefill-as-decode baseline, and paged vs contiguous KV cache.

Measures end-to-end tokens/s of the continuous-batching engine on a
prompt-heavy and a decode-heavy request mix, at several codec specs, in
both engine modes, and writes ``BENCH_serving.json`` so later perf PRs
have a recorded trajectory to beat.  A third, mixed long/short-prompt
workload compares the paged KV cache (oversubscribed page pool) against
the contiguous per-slot strips on tokens/s, mean/max time-to-first-token,
and peak cache bytes — with and without prefill/decode interleaving.
See benchmarks/README.md for the protocol and the JSON schema.

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

MIXES = {
    # name: (prompt_len, max_new_tokens) — prompt-heavy is where chunked
    # prefill pays off (O(L/C) dispatches instead of O(L)); decode-heavy
    # isolates the device-resident stepping + batched EOS fetches.
    "prompt_heavy": (64, 8),
    "decode_heavy": (8, 48),
}
SMOKE_MIXES = {"prompt_heavy": (16, 2), "decode_heavy": (4, 6)}

CODECS = ["none", "c3sl:R=4", "c3sl:R=4|int8"]
SMOKE_CODECS = ["none", "c3sl:R=2"]

# Mixed long/short workload for the paged-vs-contiguous comparison: requests
# alternate the two prompt lengths, so under the contiguous layout every
# short request still reserves a full max_len strip while the paged pool
# (sized below slots * max_len) only holds what each request can touch.
MIXED = {"long": (96, 16), "short": (8, 16), "n_each": 4}
SMOKE_MIXED = {"long": (12, 2), "short": (3, 2), "n_each": 2}


def _build(smoke: bool):
    from repro.configs.base import get_config, reduced
    from repro.models import lm as lm_lib
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run_once(cfg, params, *, mode, codec, prompt_len, max_new, requests,
              num_slots, max_len, chunk_size, sync_every, seed=0):
    from repro.serving.engine import BatchedEngine, Request
    eng = BatchedEngine(params, cfg, num_slots=num_slots, max_len=max_len,
                        codec=codec, greedy=True, seed=seed,
                        prefill_mode=mode, chunk_size=chunk_size,
                        sync_every=sync_every)
    rng = np.random.RandomState(seed + 1)

    def batch(n, uid0):
        return [Request(uid=uid0 + i,
                        prompt=list(map(int, rng.randint(1, cfg.vocab_size,
                                                         prompt_len))),
                        max_new_tokens=max_new) for i in range(n)]

    # warmup: compile every program (prefill, fused step, reset) off the clock
    for r in batch(min(2, requests), 10_000):
        eng.submit(r)
    eng.run()
    eng.finished.clear()

    reqs = batch(requests, 0)
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0
    assert len(done) == requests, (len(done), requests)
    generated = sum(len(r.out) for r in done)
    total = generated + requests * prompt_len
    return {"wall_s": round(wall, 4),
            "prompt_tokens": requests * prompt_len,
            "generated_tokens": generated,
            "tokens_per_s": round(total / wall, 1)}


def _run_mixed(cfg, params, *, kv_layout, interleave, mixed, num_slots,
               max_len, page_size, num_pages, chunk_size, sync_every, seed=0):
    """One mixed long/short run; returns throughput, TTFT, and cache bytes."""
    from repro.serving.engine import BatchedEngine, Request
    eng = BatchedEngine(params, cfg, num_slots=num_slots, max_len=max_len,
                        greedy=True, seed=seed, prefill_mode="chunked",
                        chunk_size=chunk_size, sync_every=sync_every,
                        kv_layout=kv_layout, page_size=page_size,
                        num_pages=num_pages if kv_layout == "paged" else None,
                        interleave=interleave)
    rng = np.random.RandomState(seed + 1)
    (llen, lnew), (slen, snew) = mixed["long"], mixed["short"]

    def batch(uid0):
        reqs = []
        for i in range(mixed["n_each"]):
            for ln, mn in ((llen, lnew), (slen, snew)):
                reqs.append(Request(
                    uid=uid0 + len(reqs),
                    prompt=list(map(int, rng.randint(1, cfg.vocab_size, ln))),
                    max_new_tokens=mn))
        return reqs

    for r in batch(10_000)[:2]:          # warmup: compile off the clock
        eng.submit(r)
    eng.run()
    eng.finished.clear()
    eng.stats = {k: 0 for k in eng.stats}    # count the timed run only

    reqs = batch(0)
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    wall = time.time() - t0
    assert len(done) == len(reqs), (len(done), len(reqs))
    generated = sum(len(r.out) for r in done)
    prompt_tokens = sum(len(r.prompt) for r in reqs)
    ttfts = [r.t_first - r.t_submit for r in done if r.t_first is not None]
    return {"wall_s": round(wall, 4),
            "prompt_tokens": prompt_tokens,
            "generated_tokens": generated,
            "tokens_per_s": round((prompt_tokens + generated) / wall, 1),
            "ttft_mean_s": round(sum(ttfts) / len(ttfts), 4),
            "ttft_max_s": round(max(ttfts), 4),
            "peak_cache_bytes": eng.cache_bytes,
            "dispatches": eng.stats["dispatches"]}


def bench_mixed(cfg, params, smoke, chunk_size, sync_every, results):
    """Paged vs contiguous (and the interleave knob) on the mixed workload."""
    mixed = SMOKE_MIXED if smoke else MIXED
    num_slots = 2 if smoke else 4
    max_len = 32 if smoke else 128
    page_size = 8 if smoke else 16
    # pool sized to the worst-case CONCURRENT reservation of the alternating
    # admission order (full: 2 long + 2 short = 7+7+2+2 pages), well under
    # the contiguous equivalent of slots * max_len/page_size pages
    num_pages = 3 if smoke else 18
    base = None
    for kv_layout, interleave in (("contiguous", 0), ("paged", 0),
                                  ("paged", 2)):
        # best of 2 reps (full mode): wall-clock on shared CPU runners is
        # noisy and the layouts execute identical token streams
        reps = [_run_mixed(cfg, params, kv_layout=kv_layout,
                           interleave=interleave, mixed=mixed,
                           num_slots=num_slots, max_len=max_len,
                           page_size=page_size, num_pages=num_pages,
                           chunk_size=chunk_size, sync_every=sync_every)
                for _ in range(1 if smoke else 2)]
        r = max(reps, key=lambda x: x["tokens_per_s"])
        row = {"mix": "mixed_long_short", "codec": "none", "mode": "chunked",
               "kv_layout": kv_layout, "interleave": interleave,
               "page_size": page_size if kv_layout == "paged" else None,
               "num_pages": num_pages if kv_layout == "paged" else None,
               "chunk_size": chunk_size, "sync_every": sync_every,
               "requests": 2 * mixed["n_each"], "num_slots": num_slots, **r}
        if base is None:
            base = r
        else:
            row["cache_bytes_vs_contiguous"] = round(
                r["peak_cache_bytes"] / base["peak_cache_bytes"], 3)
            row["speedup_vs_contiguous"] = round(
                r["tokens_per_s"] / base["tokens_per_s"], 2)
        results.append(row)
        print(f"mixed_long_short kv={kv_layout:10s} il={interleave} "
              f"{r['tokens_per_s']:8.1f} tok/s  ttft {r['ttft_mean_s']*1e3:7.1f}ms "
              f"(max {r['ttft_max_s']*1e3:7.1f}ms)  "
              f"cache {r['peak_cache_bytes']/1e6:6.2f}MB", flush=True)
    return results


def main(smoke: bool = False, out: str = "BENCH_serving.json",
         chunk_size: int = 16):
    cfg, params = _build(smoke)
    mixes = SMOKE_MIXES if smoke else MIXES
    codecs = SMOKE_CODECS if smoke else CODECS
    requests = 2 if smoke else 8
    num_slots = 2 if smoke else 4
    max_len = 32 if smoke else 128
    sync_every = 4 if smoke else 8

    results = []
    for mix, (prompt_len, max_new) in mixes.items():
        for spec in codecs:
            per_mode = {}
            for mode in ("decode", "chunked"):
                r = _run_once(cfg, params, mode=mode, codec=spec,
                              prompt_len=prompt_len, max_new=max_new,
                              requests=requests, num_slots=num_slots,
                              max_len=max_len, chunk_size=chunk_size,
                              sync_every=sync_every)
                per_mode[mode] = r
                results.append({"mix": mix, "codec": spec, "mode": mode,
                                "chunk_size": chunk_size if mode == "chunked" else 1,
                                "sync_every": sync_every if mode == "chunked" else 1,
                                "requests": requests, "num_slots": num_slots,
                                **r})
            speedup = (per_mode["chunked"]["tokens_per_s"]
                       / per_mode["decode"]["tokens_per_s"])
            results[-1]["speedup_vs_decode"] = round(speedup, 2)
            print(f"{mix:13s} codec={spec:16s} "
                  f"decode={per_mode['decode']['tokens_per_s']:8.1f} tok/s  "
                  f"chunked={per_mode['chunked']['tokens_per_s']:8.1f} tok/s  "
                  f"({speedup:.2f}x)", flush=True)

    bench_mixed(cfg, params, smoke, chunk_size, sync_every, results)

    payload = {
        "protocol": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "host": platform.platform(),
            "device": jax.devices()[0].platform,
            "jax": jax.__version__,
            "smoke": smoke,
        },
        "arch": {"name": cfg.name, "num_layers": cfg.num_layers,
                 "d_model": cfg.d_model, "d_ff": cfg.d_ff,
                 "vocab_size": cfg.vocab_size},
        "mixes": {k: {"prompt_len": v[0], "max_new_tokens": v[1]}
                  for k, v in mixes.items()},
        "results": results,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--chunk-size", type=int, default=16)
    args = ap.parse_args()
    main(smoke=args.smoke, out=args.out, chunk_size=args.chunk_size)
