"""Continuous-batching serving demo: 6 requests of different lengths share
3 decode slots; finished slots are recycled mid-flight.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax

from repro.configs.base import get_config, reduced
from repro.models import lm as lm_lib
from repro.serving.engine import BatchedEngine, Request


def main():
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    eng = BatchedEngine(params, cfg, num_slots=3, max_len=64)

    for i in range(6):
        eng.submit(Request(uid=i, prompt=list(range(1 + i, 5 + i)),
                           max_new_tokens=4 + 2 * i))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"completed {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s) through 3 slots")
    for r in sorted(done, key=lambda r: r.uid):
        print(f"  req {r.uid}: prompt {r.prompt} -> {r.out}")
    assert len(done) == 6


if __name__ == "__main__":
    main()
