"""Quickstart: train a small causal LM through the C3-SL boundary codec.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end to end on CPU in ~a minute:
  1. pick an assigned architecture config, reduce it to laptop scale,
  2. insert the C3-SL codec at the stack midpoint (R=4 batch-wise HRR),
  3. train a few hundred steps on the synthetic token task,
  4. report loss curve + boundary-traffic savings.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.codecs import build
from repro.configs.base import get_config, reduced
from repro.core.metrics import comm_report
from repro.data.pipeline import SyntheticTokenDataset
from repro.models import lm as lm_lib
from repro.optim import adamw, apply_updates, clip_by_global_norm

STEPS = int(os.environ.get("QUICKSTART_STEPS", 120))


def main():
    cfg = reduced(get_config("deepseek-7b"), num_layers=4, d_model=128,
                  d_ff=256, vocab_size=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    B, S, R = 16, 64, 4
    codec = build(f"c3sl:R={R}", D=S * cfg.d_model)

    rng = jax.random.PRNGKey(0)
    params = lm_lib.init_lm_params(rng, cfg)
    codec_params = codec.init(jax.random.PRNGKey(1))
    opt = adamw(3e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_lib.lm_loss(p, batch, cfg, codec=codec,
                                     codec_params=codec_params))(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    data = SyntheticTokenDataset(cfg.vocab_size, S, seed=0)
    losses = []
    for i in range(STEPS):
        params, opt_state, loss = step(params, opt_state, data.batch(B, i))
        losses.append(float(loss))
        if i % 20 == 0:
            print(f"step {i:4d}  loss {losses[-1]:.4f}")

    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'OK' if losses[-1] < losses[0] else 'NOT LEARNING'})")
    print(comm_report(codec, B, S * cfg.d_model).row())
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
