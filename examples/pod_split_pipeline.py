"""The paper's topology at datacenter scale: 2-stage pod pipeline where the
transport layer compresses the inter-pod channel (ppermute) in BOTH
directions — each direction with its OWN codec (the backward gradient
payload re-grouped by the ``bwd:`` channel), and the channel double-buffered
(``async_depth=2``) so microbatch t's payload send overlaps microbatch
t+1's front pass.

    PYTHONPATH=src python examples/pod_split_pipeline.py

Runs on 8 simulated host devices as a (pod=2, data=2, model=2) mesh; prints
the loss curve and the per-direction channel-bytes saving vs uncompressed.
This is the runnable small-scale twin of the production (2,16,16) dry-run.
"""
import os, sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import transport
from repro.configs.base import get_config, reduced
from repro.launch import mesh as mesh_lib
from repro.models import lm as lm_lib
from repro.data.pipeline import SyntheticTokenDataset
from repro.optim import adamw, apply_updates, clip_by_global_norm

STEPS = int(os.environ.get("PIPELINE_STEPS", 30))
ASYNC_DEPTH = int(os.environ.get("PIPELINE_ASYNC_DEPTH", 2))


def main():
    cfg = reduced(get_config("deepseek-7b"), num_layers=4, d_model=128,
                  d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    mesh = mesh_lib.make_host_mesh(data=2, model=2, pod=2)
    B, S, M, R = 32, 32, 4, 4     # mb=8: fwd R=4 leaves 2 gradient rows
    mb = B // M                   # for the bwd channel's R=2 grouping
    # forward: R=4 + int8 wire; backward: the gradient payload (mb/R rows)
    # re-grouped by its own R=2 — the per-direction transport link
    codec = transport.build_link(
        f"c3sl:R={min(R, mb)}|int8 >> bwd:c3sl:R=2|int8", D=S * cfg.d_model)

    rng = jax.random.PRNGKey(0)
    full = lm_lib.init_lm_params(rng, cfg)
    params = {
        "embed": {"embed": full["embed"]},
        "blocks": lm_lib.split_stack_for_pipeline(full["stack"]),
        "head": {"final_norm": full["final_norm"], "head": full["head"]},
        "codec": codec.init(jax.random.PRNGKey(7)),
    }
    embed_fn, stage_fn, head_loss_fn = lm_lib.make_pipeline_fns(cfg)
    loss_fn = transport.make_pod_pipeline_loss_fn(
        embed_fn, stage_fn, head_loss_fn, codec, mesh, num_microbatches=M,
        async_depth=ASYNC_DEPTH)

    opt = adamw(3e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    data = SyntheticTokenDataset(cfg.vocab_size, S, seed=0)
    losses = []
    with mesh_lib.set_mesh(mesh):
        for i in range(STEPS):
            b = data.batch(B, i)
            params, opt_state, loss = step(
                params, opt_state, {"x": b["tokens"], "y": b["labels"]})
            losses.append(float(loss))
            if i % 5 == 0:
                print(f"step {i:3d} loss {losses[-1]:.4f}")

    wf = codec.wire_bytes_fwd(mb)
    wb = codec.wire_bytes_bwd(mb)
    base = mb * S * cfg.d_model * 4
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"inter-pod bytes per microbatch (async_depth={ASYNC_DEPTH}): "
          f"fwd {wf:,} + bwd {wb:,} vs {2 * base:,} uncompressed "
          f"({2 * base / (wf + wb):.1f}x)")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
