"""Serving example: batched token-by-token decode with the C3-SL codec
compressing the cut-layer activations across the decode batch.

    PYTHONPATH=src python examples/serve_decode.py

Uses the attention-free rwkv6 family (O(1) decode state) at reduced scale;
prints throughput and boundary-compression stats.  Equivalent to:
    python -m repro.launch.serve --arch rwkv6-1.6b --reduced --codec c3sl
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import subprocess

if __name__ == "__main__":
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "rwkv6-1.6b",
         "--reduced", "--batch", "8", "--steps", "24", "--codec", "c3sl",
         "--R", "4"], env=env))
