"""Paper reproduction example: VGG-16-style split learning on CIFAR-shaped
synthetic data, comparing vanilla SL / C3-SL / BottleNet++ at R=4.

    PYTHONPATH=src python examples/split_cifar.py [--steps 200]

This is the end-to-end driver for the paper's Table 1 experiment at laptop
scale (offline container: class-conditional synthetic images stand in for
CIFAR; the trend — C3-SL ~= vanilla accuracy with R x less traffic and
~1000x fewer codec params than BottleNet++ — is the reproduction target).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax

from benchmarks.bench_accuracy import CUT, D, run_one
from repro.codecs import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    rng = jax.random.PRNGKey(0)

    print(f"{'method':>12s} {'acc%':>6s} {'codec params':>12s} {'wire bytes/step':>16s}")
    van = run_one(None, {}, steps=args.steps)
    print(f"{'vanilla':>12s} {van*100:6.1f} {0:12d} {64*D*4*2:16d}")

    for R in (2, 4, 8, 16):
        c = build(f"c3sl:R={R}", D=D)
        acc = run_one(c, c.init(rng), steps=args.steps)
        print(f"{f'c3sl R={R}':>12s} {acc*100:6.1f} {c.param_count():12d} "
              f"{2*c.wire_bytes(64):16d}")

    bn = build(f"bnpp:R=4,C={CUT[0]},H={CUT[1]},W={CUT[2]}")
    acc = run_one(bn, bn.init(rng), steps=args.steps)
    print(f"{'bnpp R=4':>12s} {acc*100:6.1f} {bn.param_count():12d} "
          f"{2*bn.wire_bytes(64):16d}")


if __name__ == "__main__":
    main()
